"""Public entry points for the Pallas kernels (checking, VJPs, dispatch).

The dispatch mirrors the paper's co-design argument:

* ``offset_bound`` given (the Eq. 5-trained model) -> the Pallas
  bounded-halo kernels: static HBM->VMEM bands, no irregular HBM access.
* ``offset_bound`` None (the lambda=0 baseline) -> the pure-XLA gather
  path of ``repro.core.deform_conv`` — dynamic gathers from HBM, exactly
  the "irregular DRAM access" regime the paper measures against.

Every bounded kernel is emitted by the unified band-pipeline emitter
(``kernels.band_pipeline`` — ``BandSpec``/``DCLPlan`` + the
double-buffered ``make_async_copy`` band stager); the plan building and
the runner bodies live in ``kernels.plan`` (see ``docs/kernels.md``).
This module is the thin public surface: argument checking, mesh/shard
resolution, the ``jax.custom_vjp`` wiring, and the precision dispatch.

Bounded kernels support two dataflows (``dataflow=``):

* ``"zero_copy"`` (default) — the input is zero-padded once and handed
  whole to the kernel in ``ANY``/HBM memory space; the kernel issues
  double-buffered ``make_async_copy`` DMAs per Eq. 6 (row, width) band.
  Nothing is duplicated in HBM and VMEM is bounded independent of image
  size.  Tile sizes default to the Sec. 3.2 chooser
  (``repro.core.tiling.choose_kernel_tiles``); pass explicit tiles to
  override.
* ``"banded"`` (legacy) — ``plan.pad_and_band`` materializes overlapping
  full-width row bands in HBM via an XLA gather (a
  ``band_h/(tile_h*stride)`` ~ 2-3x duplication of the input) before
  the kernel runs.  Kept as the parity baseline; see EXPERIMENTS.md
  §Perf for the modeled traffic difference.

``interpret`` defaults to True off-TPU (this container is CPU-only); on
a real TPU backend it auto-disables.

The bounded ``deform_conv`` path is differentiable: it is wrapped in a
``jax.custom_vjp`` whose backward is the fused zero-copy kernel of
``deform_conv_bwd.py`` (d_input, d_offsets, d_weights in one band-DMA
pass), so Eq. 5-bounded *training* also runs the zero-copy dataflow —
never an XLA gather/scatter against HBM.

``deform_conv(precision="int8")`` dispatches the quantized inference
datapath: symmetric int8 band DMA + int8 MXU contraction with int32
accumulation, fp32 bilinear coefficients, fused per-out-channel dequant
epilogue — tiles resolved against the dtype-aware budgets (4x Eq. 6
band density).  Scales come from ``repro.quant`` calibration or dynamic
absmax.

``deform_conv_chain`` is the int8 layer-chaining entry (ROADMAP int8
follow-ups, both): the offset conv is fused into the kernel (an int8
MXU stage over the already-staged Eq. 6 band — no separate fp32 offset
pass, no offsets in HBM) and the output is emitted int8 on the *next*
layer's activation grid via a fused per-channel requant, so
back-to-back DCLs chain int8 -> int8 with no fp32 HBM round-trip
between layers (``models.layers.dcl_apply(quant="int8_chain")``).

Parallel training (PR 4), two composable levels:

* ``cores=`` splits the *backward* kernel's batch grid axis into
  per-core shards (Megacore ``parallel`` dimension semantics; see
  ``deform_conv_bwd.py``) with a cheap per-core ``d_weights`` reduce
  epilogue.
* When a mesh is active (``distributed.sharding.use_rules(mesh=...)``)
  and the 'batch' logical axis maps to real mesh axes, the bounded
  fp32 path wraps itself in ``shard_map`` over those axes: each device
  runs the full zero-copy fwd/bwd kernels on its batch shard and the
  custom VJP psums ``d_weights`` across the data axes — data-parallel
  DCL training never falls back to GSPMD partitioning the kernel
  internals (which replicates / re-gathers).  ``shard_batch`` selects
  the mode: None (auto: shard when the mesh divides the batch),
  True (require sharding — non-divisible batches raise), False (never).
"""
from __future__ import annotations

import contextlib
import dataclasses
import functools
import logging

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

from repro.core.deform_conv import DCLConfig, sample_patches
from repro.distributed.sharding import batch_mesh_axes
from repro.distributed import spatial as _spatial
from . import plan as _plan
from .deform_sample import deform_sample_banded, deform_sample_zerocopy
from .matmul import matmul  # re-export  # noqa: F401
from .plan import (DCSpec as _DCSpec, chain_forward, int8_forward,
                   resolve_tiles, tile_weights, untile_weights)

Array = jax.Array

DEFAULT_DATAFLOW = "zero_copy"

# Back-compat aliases (tests and older callers import the underscored
# names from here).
_pad_and_band = _plan.pad_and_band
_pad_zerocopy = _plan.pad_zerocopy
_zerocopy_inputs = _plan.zerocopy_inputs
_bounded_forward = _plan.bounded_forward
_bounded_backward = _plan.bounded_backward
_spec_tiles = _plan.spec_tiles


def default_interpret() -> bool:
    """Whether the Pallas kernels run in interpret mode by default —
    now a view of the process-global lowering platform
    (``launch.platform``): Mosaic (False) only under platform 'tpu'."""
    from repro.launch.platform import current_platform
    return current_platform() != "tpu"


# ---------------------------------------------------------------------------
# Graceful degradation (PR 6).
#
# Argument validation (bad tiles, missing scales, unknown modes) is
# hoisted into the un-jitted public wrappers and still RAISES — a wrong
# call is a caller bug, and the friendly ValueErrors are part of the
# API.  Failures past validation — plan resolution, the emitter, kernel
# lowering, or an injected dispatch fault — are bounded-path problems a
# correct XLA graph can serve, so the wrappers fall back to the
# reference path (``ref.deform_conv_fused_ref`` / the fake-quant
# oracles of ``repro.quant.qat``) with exactly one warning per
# (entry, precision) on the ``repro.resilience`` logger.  The ladder:
# int8_chain -> int8 -> fp32 kernel -> XLA reference
# (docs/robustness.md); each rung's fallback is the reference form of
# the SAME arithmetic, so degraded outputs stay parity-close.
#
# ``set_dispatch_hook`` installs a callable consulted (with a context
# dict) before each bounded dispatch — the chaos harness's injection
# seam.  It lives in the un-jitted wrappers on purpose: inside the
# jitted impl it would fire once per trace, then never again.
# ---------------------------------------------------------------------------

_log = logging.getLogger("repro.resilience")

_dispatch_hook = None
_degrade_enabled = True
_FALLBACK_WARNED: set = set()


def set_dispatch_hook(hook):
    """Install (or clear, with None) the dispatcher hook; returns the
    previous hook.  Called as ``hook(context_dict)`` before every
    bounded kernel dispatch; raising aborts the kernel path and
    triggers the degradation fallback.

    ISSUE 8: a hook may RETURN a ``finish(out=None, error=None)``
    callable, which the wrapper invokes after the kernel call resolves
    (success or failure) — the measurement seam
    ``repro.obs.DispatchRecorder`` closes its per-dispatch timing
    through.  A None return (the chaos harness) keeps the old
    fire-and-forget contract."""
    global _dispatch_hook
    prev, _dispatch_hook = _dispatch_hook, hook
    return prev


def get_dispatch_hook():
    """The currently installed dispatcher hook (None if clear) — lets
    per-engine instrumentation CHAIN an outer hook (chaos injection)
    instead of shadowing it."""
    return _dispatch_hook


def set_degradation(enabled: bool):
    """Toggle the reference fallback; returns the previous setting.
    With degradation off, post-validation failures raise (the strict
    mode the parity test-suites run under when they WANT the kernel)."""
    global _degrade_enabled
    prev, _degrade_enabled = _degrade_enabled, bool(enabled)
    return prev


def reset_fallback_warnings() -> None:
    """Forget which entry points already warned (tests)."""
    _FALLBACK_WARNED.clear()


@contextlib.contextmanager
def degradation_scope(enabled: bool):
    """Scoped :func:`set_degradation` with guaranteed restore.

    The serving engine wraps each batch in ``degradation_scope(False)``
    so kernel failures surface as exceptions it converts into its OWN
    per-request ladder (retry, then drop a rung, recorded in request
    telemetry) instead of this module's process-global warn-once
    fallback — two engines in one process never share degradation
    state (docs/serving.md)."""
    prev = set_degradation(enabled)
    try:
        yield
    finally:
        set_degradation(prev)


@contextlib.contextmanager
def dispatch_hook_scope(hook):
    """Scoped :func:`set_dispatch_hook` with guaranteed restore — the
    save/restore idiom chaos tests and per-engine instrumentation use
    so a raising body cannot leak a hook into unrelated callers."""
    prev = set_dispatch_hook(hook)
    try:
        yield
    finally:
        set_dispatch_hook(prev)


def _consult_dispatch_hook(**context):
    """Run the installed hook; returns its result (a ``finish``
    callable, or None).  A raising hook aborts the kernel path."""
    if _dispatch_hook is not None:
        return _dispatch_hook(context)
    return None


def _finish_dispatch(finish, out=None, error=None) -> None:
    """Close a hook's measurement.  Observability must never break the
    dispatch: a non-callable ``finish`` is ignored and a raising one is
    swallowed (debug-logged) — the kernel result/degradation decision
    was already made."""
    if not callable(finish):
        return
    try:
        finish(out=out, error=error)
    except Exception as e:  # noqa: BLE001 — never propagate from obs
        _log.debug("dispatch finish hook raised: %s: %s",
                   type(e).__name__, e)


def _degraded(key: tuple, err: Exception, fallback):
    """Run ``fallback()`` after logging the first degradation of
    ``key``; re-raise if degradation is disabled."""
    if not _degrade_enabled:
        raise err
    if key not in _FALLBACK_WARNED:
        _FALLBACK_WARNED.add(key)
        _log.warning(
            "%s: bounded kernel path failed (%s: %s); degrading to the "
            "XLA reference path (warned once per entry point — see "
            "docs/robustness.md)", "/".join(key), type(err).__name__, err)
    return fallback()


def check_channel_tiles(c: int, m: int, tile_c: int | None,
                        tile_m: int | None = None) -> None:
    """Reject channel tiles that don't divide the layer — a clear
    ``ValueError`` at the public entry instead of a deep Pallas
    BlockSpec shape error (or a bare kernel assert) later."""
    if tile_c is not None and c % tile_c != 0:
        raise ValueError(
            f"tile_c={tile_c} does not divide C={c}; the fused kernels "
            f"step the channel axis in contiguous tile_c chunks — pass a "
            f"divisor of C (or tile_c=None for the Sec. 3.2 chooser, "
            f"which snaps to divisors)")
    if tile_m is not None and m % tile_m != 0:
        raise ValueError(
            f"tile_m={tile_m} does not divide M={m}; the output-channel "
            f"grid axis needs a divisor of M (or tile_m=None for the "
            f"chooser)")


def check_batch_split(n: int, *, cores: int = 1,
                      shard_of: int | None = None) -> None:
    """Reject batch splits that don't divide the batch — a clear
    ``ValueError`` at the public entry (à la ``check_channel_tiles``)
    instead of a deep Pallas grid assert / shard_map shape error later.

    ``shard_of`` names the pre-shard global batch in the message when
    ``n`` is already a per-device shard (mesh sharding composes with
    the core split: each device's shard is further split over cores).
    """
    if cores < 1:
        raise ValueError(f"cores={cores} must be >= 1")
    if n % cores != 0:
        ctx = (f" (per-device shard of global batch N={shard_of})"
               if shard_of is not None else "")
        raise ValueError(
            f"cores={cores} does not divide batch N={n}{ctx}; the "
            f"Megacore backward splits the batch grid axis into "
            f"per-core shards — pass a divisor of the batch (or "
            f"cores=1 for the sequential backward kernel)")


@dataclasses.dataclass(frozen=True)
class _ShardSpec:
    """Hashable mesh context of one batch-sharded deform_conv call."""
    mesh: Mesh
    axes: tuple[str, ...]

    def pspec(self, rank: int) -> P:
        """Full-rank PartitionSpec sharding dim 0 over the batch axes."""
        return P(self.axes, *([None] * (rank - 1)))


def resolve_batch_shard(n: int, *, shard_batch: bool | None = None,
                        cores: int = 1) -> _ShardSpec | None:
    """Decide whether (and how) to shard the batch axis over the active
    mesh, validating the core split either way.

    * ``shard_batch=None`` (auto): shard iff a mesh is active under
      ``distributed.sharding.use_rules`` and its batch-mapped axes
      divide ``n``; otherwise run unsharded (same silent-fallback
      philosophy as ``logical_spec``).
    * ``shard_batch=True``: require sharding — no active mesh or a
      non-dividing batch raises a ``ValueError`` naming the sizes.
    * ``shard_batch=False``: never shard.
    """
    got = batch_mesh_axes() if shard_batch is not False else None
    if got is None:
        if shard_batch:
            raise ValueError(
                "shard_batch=True but no mesh maps the 'batch' logical "
                "axis — activate one with distributed.sharding."
                "use_rules(mesh=...) (axes of size > 1 required)")
        check_batch_split(n, cores=cores)
        return None
    mesh, axes, size = got
    if n % size != 0:
        if shard_batch:
            raise ValueError(
                f"batch N={n} does not divide the mesh batch axes "
                f"{axes} (total size {size}); the shard_map kernel "
                f"path needs equal per-device shards — pad the batch "
                f"to a multiple of {size} or pass shard_batch=False")
        check_batch_split(n, cores=cores)
        return None
    check_batch_split(n // size, cores=cores, shard_of=n)
    return _ShardSpec(mesh=mesh, axes=axes)


@functools.partial(
    jax.jit,
    static_argnames=("kernel_size", "stride", "dilation", "offset_bound",
                     "tile_h", "tile_w", "tile_c", "dataflow", "interpret"))
def deform_sample(x: Array, offsets: Array, *, kernel_size: int = 3,
                  stride: int = 1, dilation: int = 1,
                  offset_bound: float | None = None,
                  tile_h: int | None = 8, tile_w: int | None = None,
                  tile_c: int | None = None,
                  dataflow: str = DEFAULT_DATAFLOW,
                  interpret: bool | None = None) -> Array:
    """Stage 1: bilinear patch sampling.

    x: (N, H, W, C); offsets: (N, Ho, Wo, 2*K*K) raw offset-conv output.
    Returns (N, Ho, Wo, K*K, C).
    """
    n, h, w, c = x.shape
    ho, wo = offsets.shape[1], offsets.shape[2]
    k2 = kernel_size * kernel_size

    if offset_bound is None:
        # Unbounded model: irregular-gather baseline (paper's lambda=0).
        cfg = DCLConfig(in_channels=c, out_channels=1,
                        kernel_size=kernel_size, stride=stride,
                        dilation=dilation)
        return sample_patches(x, offsets.reshape(n, ho, wo, k2, 2), cfg)

    if interpret is None:
        interpret = default_interpret()
    check_channel_tiles(c, c, tile_c)

    if dataflow == "banded":
        th = tile_h or 8
        pad_h = (-ho) % th
        if pad_h:
            offsets = jnp.pad(offsets, ((0, 0), (0, pad_h), (0, 0), (0, 0)))
        bands, n_tiles = _plan.pad_and_band(
            x, kernel_size=kernel_size, stride=stride, dilation=dilation,
            offset_bound=offset_bound, tile_h=th, ho=ho + pad_h)
        patches = deform_sample_banded(
            bands, offsets, kernel_size=kernel_size, stride=stride,
            dilation=dilation, offset_bound=offset_bound, tile_h=th,
            tile_c=tile_c, interpret=interpret)
        return patches[:, :ho]

    if dataflow != "zero_copy":
        raise ValueError(
            f"unknown dataflow {dataflow!r}; expected 'zero_copy' or "
            f"'banded'")
    th, tw, tc, _ = resolve_tiles(
        h, w, c, c, kernel_size=kernel_size, stride=stride,
        dilation=dilation, offset_bound=offset_bound, tile_h=tile_h,
        tile_w=tile_w, tile_c=tile_c, tile_m=c, objective="forward")
    th, tw = min(th, ho), min(tw, wo)
    pad_h, pad_w = (-ho) % th, (-wo) % tw
    if pad_h or pad_w:
        offsets = jnp.pad(offsets,
                          ((0, 0), (0, pad_h), (0, pad_w), (0, 0)))
    xp = _plan.pad_zerocopy(
        x, kernel_size=kernel_size, stride=stride, dilation=dilation,
        offset_bound=offset_bound, tile_h=th, tile_w=tw,
        ho=ho + pad_h, wo=wo + pad_w)
    patches = deform_sample_zerocopy(
        xp, offsets, kernel_size=kernel_size, stride=stride,
        dilation=dilation, offset_bound=offset_bound, tile_h=th, tile_w=tw,
        tile_c=tc, interpret=interpret)
    return patches[:, :ho, :wo]


# ---------------------------------------------------------------------------
# Bounded path: custom VJP over the emitted kernels.
#
# Forward runs the zero-copy (or legacy banded) fused kernel; backward
# runs the fused zero-copy backward kernel of ``deform_conv_bwd.py``
# regardless of the forward dataflow (gradients are a property of the
# math, not the dataflow — both forwards match ``ref.py`` bit-for-near).
# Residuals are just (x, offsets, w): patches are recomputed in-kernel
# from the Eq. 6 band, which the traffic model favors over saving the
# (N, Ho, Wo, K^2, C) patch tensor (see ``deform_conv_bwd.py``).  The
# runner bodies live in ``kernels.plan``.
# ---------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def _deform_conv_bounded(spec: _DCSpec, x: Array, offsets: Array,
                         w: Array) -> Array:
    return _plan.bounded_forward(spec, x, offsets, w)


def _deform_conv_bounded_fwd(spec, x, offsets, w):
    return _plan.bounded_forward(spec, x, offsets, w), (x, offsets, w)


def _deform_conv_bounded_bwd(spec, res, gy):
    x, offsets, w = res
    return _plan.bounded_backward(spec, x, offsets, w, gy)


_deform_conv_bounded.defvjp(_deform_conv_bounded_fwd,
                            _deform_conv_bounded_bwd)


# ---------------------------------------------------------------------------
# Mesh-sharded bounded path: shard_map over the batch axis, custom VJP
# with an explicit d_weights psum epilogue.
#
# The custom_vjp wraps the shard_maps (one for forward, one for
# backward) rather than the other way round, so gradient correctness
# never depends on shard_map's transpose rules: each device runs the
# zero-copy kernels on its batch shard; d_input/d_offsets are
# batch-sharded like their primals, and the replicated weights'
# cotangent is psummed across the batch mesh axes inside the backward
# body (this also covers the QAT fake-quant path — the STE wrappers
# act on the replicated weights *outside* this function, so the psummed
# kernel dw is exactly the cotangent they consume).
# ---------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(0, 1))
def _deform_conv_sharded(spec: _DCSpec, shard: _ShardSpec, x: Array,
                         offsets: Array, w: Array) -> Array:
    pb = shard.pspec(4)
    fn = shard_map(functools.partial(_plan.bounded_forward, spec),
                   mesh=shard.mesh,
                   in_specs=(pb, pb, P(None, None, None)),
                   out_specs=pb, check_rep=False)
    return fn(x, offsets, w)


def _deform_conv_sharded_fwd(spec, shard, x, offsets, w):
    return _deform_conv_sharded(spec, shard, x, offsets, w), (x, offsets, w)


def _deform_conv_sharded_bwd(spec, shard, res, gy):
    x, offsets, w = res
    pb = shard.pspec(4)
    rep_w = P(None, None, None)

    def body(x, offsets, w, gy):
        dx, doff, dw = _plan.bounded_backward(spec, x, offsets, w, gy)
        # psum epilogue: w is replicated across the batch axes, so its
        # cotangent is the sum of every shard's partial d_weights.
        return dx, doff, jax.lax.psum(dw, shard.axes)

    fn = shard_map(body, mesh=shard.mesh,
                   in_specs=(pb, pb, rep_w, pb),
                   out_specs=(pb, pb, rep_w), check_rep=False)
    return fn(x, offsets, w, gy)


_deform_conv_sharded.defvjp(_deform_conv_sharded_fwd,
                            _deform_conv_sharded_bwd)


@functools.partial(
    jax.jit,
    static_argnames=("kernel_size", "stride", "dilation", "offset_bound",
                     "tile_h", "tile_w", "tile_c", "tile_m", "dataflow",
                     "precision", "cores", "shard", "spatial", "interpret",
                     "dw_flush_every_step"))
def _deform_conv_impl(x: Array, offsets: Array, w: Array, *,
                      kernel_size: int, stride: int, dilation: int,
                      offset_bound: float | None,
                      tile_h: int | None, tile_w: int | None,
                      tile_c: int | None, tile_m: int | None,
                      dataflow: str, precision: str, cores: int,
                      shard: _ShardSpec | None,
                      spatial: _spatial.SpatialSpec | None,
                      x_scale: Array | None, w_scale: Array | None,
                      interpret: bool | None,
                      dw_flush_every_step: bool | None = None) -> Array:
    # NOTE: argument validation lives in the un-jitted ``deform_conv``
    # wrapper (hoisted in PR 6 so validation errors always raise while
    # post-validation failures can degrade to the reference path).
    n, h, w_, c = x.shape
    ho, wo = offsets.shape[1], offsets.shape[2]
    k2 = kernel_size * kernel_size
    m = w.shape[-1]

    if precision == "int8":
        if interpret is None:
            interpret = default_interpret()
        if spatial is not None:
            return _spatial.spatial_int8_forward(
                x, offsets, w, kernel_size=kernel_size, stride=stride,
                dilation=dilation, offset_bound=offset_bound,
                tile_h=tile_h, tile_w=tile_w, tile_c=tile_c,
                tile_m=tile_m, x_scale=x_scale, w_scale=w_scale,
                interpret=interpret, sspec=spatial)
        return int8_forward(
            x, offsets, w, kernel_size=kernel_size, stride=stride,
            dilation=dilation, offset_bound=offset_bound, tile_h=tile_h,
            tile_w=tile_w, tile_c=tile_c, tile_m=tile_m,
            x_scale=x_scale, w_scale=w_scale, interpret=interpret)

    if offset_bound is None:
        cfg = DCLConfig(in_channels=c, out_channels=m,
                        kernel_size=kernel_size, stride=stride,
                        dilation=dilation)
        patches = sample_patches(x, offsets.reshape(n, ho, wo, k2, 2), cfg)
        y = jnp.einsum("nhwkc,kcm->nhwm", patches, w,
                       preferred_element_type=jnp.float32)
        return y.astype(x.dtype)

    if interpret is None:
        interpret = default_interpret()
    spec = _DCSpec(kernel_size=kernel_size, stride=stride, dilation=dilation,
                   offset_bound=offset_bound, tile_h=tile_h, tile_w=tile_w,
                   tile_c=tile_c, tile_m=tile_m, dataflow=dataflow,
                   interpret=interpret, cores=cores,
                   dw_flush_every_step=dw_flush_every_step)
    if spatial is not None:
        return _spatial.deform_conv_spatial(spec, spatial, x, offsets, w)
    if shard is not None:
        return _deform_conv_sharded(spec, shard, x, offsets, w)
    return _deform_conv_bounded(spec, x, offsets, w)


@functools.partial(
    jax.jit,
    static_argnames=("kernel_size", "stride", "dilation", "offset_bound",
                     "precision"))
def _reference_impl(x: Array, offsets: Array, w: Array, *,
                    kernel_size: int, stride: int, dilation: int,
                    offset_bound: float, precision: str,
                    x_scale: Array | None,
                    w_scale: Array | None) -> Array:
    """The platform='xla_ref' lowering (``launch.platform``): the
    degradation ladder's reference forms of the bounded arithmetic,
    compiled as ordinary XLA — the parity baseline the tuner and the
    test-suite compare the emitted kernels against.  Differentiable
    (plain XLA graph), so the training objective works here too."""
    if precision == "int8":
        from repro.quant.qat import fake_quant_dcl_reference
        return fake_quant_dcl_reference(
            x, offsets, w, kernel_size=kernel_size, stride=stride,
            dilation=dilation, offset_bound=offset_bound,
            x_scale=x_scale, w_scale=w_scale)
    return _plan.reference_forward(
        x, offsets, w, kernel_size=kernel_size, stride=stride,
        dilation=dilation, offset_bound=offset_bound)


def deform_conv(x: Array, offsets: Array, w: Array, *, kernel_size: int = 3,
                stride: int = 1, dilation: int = 1,
                offset_bound: float | None = None,
                tile_h: int | None = None, tile_w: int | None = None,
                tile_c: int | None = None, tile_m: int | None = None,
                dataflow: str = DEFAULT_DATAFLOW,
                precision: str = "fp32",
                cores: int = 1,
                shard_batch: bool | None = None,
                shard_spatial: bool | None = None,
                x_scale: Array | None = None,
                w_scale: Array | None = None,
                interpret: bool | None = None,
                dw_flush_every_step: bool | None = None) -> Array:
    """Fused DCL stage 1+2: y = g(x, o) * w_deform  (Eq. 2).

    x: (N, H, W, C); offsets: (N, Ho, Wo, 2*K*K); w: (K*K, C, M).
    Returns (N, Ho, Wo, M).  Unspecified tile sizes are resolved by the
    Sec. 3.2 chooser against the combined fwd+bwd zero-copy traffic
    model.  The bounded path is differentiable end-to-end: ``jax.grad``
    routes through the fused backward kernel of ``deform_conv_bwd.py``
    (a ``jax.custom_vjp``), never through an XLA gather/scatter.

    ``cores`` splits the backward kernel's batch grid axis per Megacore
    core (``parallel`` dimension semantics + per-core d_weights reduce;
    must divide the per-device batch — ``check_batch_split`` raises the
    friendly error).  ``shard_batch`` controls the data-parallel
    ``shard_map`` wrap of the bounded fp32 path over the active mesh's
    batch axes (see ``resolve_batch_shard``: None = auto, True =
    require, False = never).  Sharding is resolved OUTSIDE this
    function's own jit boundary from
    ``distributed.sharding.current_rules()`` — the mesh context is a
    static cache key of ``_deform_conv_impl``, so eager/top-level calls
    under different ``use_rules`` contexts never reuse a stale layout.
    The usual jit caveat still applies one level up: a CALLER's
    ``jax.jit`` bakes the context seen at its own trace time into its
    cache (the Trainer builds its step inside ``use_rules(mesh=...)``
    and keeps one mesh per instance for exactly this reason); pass
    ``shard_batch=True`` to fail loudly instead of silently running
    unsharded when the mesh matters.

    ``precision="int8"`` (bounded zero-copy only) runs the quantized
    inference datapath: int8 band DMA + int8 MXU contraction with int32
    accumulation, fp32 bilinear coefficients, fused per-out-channel
    dequant epilogue.  ``x_scale`` (per-tensor) / ``w_scale``
    (per-out-channel, shape (M,)) override the dynamic absmax observers
    with calibrated values (``repro.quant.calibrate``); tiles resolve
    against the int8 dtype-aware budgets (4x Eq. 6 band density per
    VMEM byte).

    ``shard_spatial=True`` (ISSUE 10) height-shards the bounded
    zero-copy call over the mesh axis the 'spatial' logical axis maps
    to (``distributed.spatial``): one ``lax.ppermute`` halo-exchange
    pair of the statically bounded ``B + ceil(K/2)`` rows per call,
    then the unmodified per-shard kernel — single-image latency
    scaling for megapixel inputs.  Strictly opt-in (None/False = off);
    requires an active mesh, ``H % (stride*shards) == 0``, and the
    zero-copy dataflow.  Works for fp32 (differentiable — halo
    gradients are returned to their owning shards and ``d_weights`` is
    psummed) and int8 (inference, scales hoisted above the shard_map);
    composes with ``shard_batch`` into a spatial x data 2-D mesh and
    with the Megacore ``cores`` split.
    """
    # -- validation (always raises; never degraded) -------------------
    c, m = x.shape[-1], w.shape[-1]
    if precision not in ("fp32", "int8"):
        raise ValueError(
            f"unknown precision {precision!r}; expected 'fp32' or 'int8'")
    if dataflow not in ("zero_copy", "banded"):
        raise ValueError(
            f"unknown dataflow {dataflow!r}; expected 'zero_copy' or "
            f"'banded'")
    check_channel_tiles(c, m, tile_c, tile_m)
    if precision == "int8":
        if offset_bound is None:
            raise ValueError(
                "precision='int8' requires a trained offset_bound — the "
                "quantized datapath exists because Eq. 6 bounds the band; "
                "the unbounded gather baseline has no int8 kernel")
        if dataflow != "zero_copy":
            raise ValueError(
                f"precision='int8' supports only the zero-copy dataflow "
                f"(got {dataflow!r})")

    shard = None
    spatial = None
    if shard_spatial:
        if offset_bound is None:
            raise ValueError(
                "shard_spatial=True requires a trained offset_bound — "
                "the halo exchange is statically bounded by Eq. 5/6 "
                "(B + ceil(K/2) rows); the unbounded gather baseline "
                "has no bounded halo and partitions via GSPMD instead")
        if dataflow != "zero_copy":
            raise ValueError(
                f"shard_spatial=True supports only the zero-copy "
                f"dataflow (got {dataflow!r}); the legacy banded path "
                f"materializes full-width bands and has no per-shard "
                f"slab to run on")
    if offset_bound is not None and precision == "fp32":
        shard = resolve_batch_shard(x.shape[0], shard_batch=shard_batch,
                                    cores=cores)
    else:
        if shard_batch:
            raise ValueError(
                "shard_batch=True requires the bounded fp32 kernel path "
                "(offset_bound set, precision='fp32'); the unbounded "
                "gather baseline and the int8 inference datapath "
                "partition via GSPMD instead")
        if cores != 1:
            raise ValueError(
                f"cores={cores} applies to the bounded fp32 kernel path "
                f"(offset_bound set, precision='fp32') — only its fused "
                f"backward has the Megacore batch split; this call "
                f"dispatches the "
                f"{'int8 inference' if precision == 'int8' else 'unbounded gather'} "
                f"path, so pass cores=1")
        if dw_flush_every_step is not None:
            raise ValueError(
                f"dw_flush_every_step={dw_flush_every_step} applies to "
                f"the bounded fp32 kernel path (offset_bound set, "
                f"precision='fp32') — it is the d_weights flush cadence "
                f"of the fused backward kernel; pass None here")
    if shard_spatial:
        # Spatial sharding resolves AFTER the batch shard so a 2-D
        # spatial x data mesh folds the batch axes into one shard_map
        # (the SpatialSpec carries them; the plain batch path is then
        # subsumed).  Validation (active mesh, even height split,
        # halo-thin shards) raises inside resolve_spatial_shard.
        spatial = _spatial.resolve_spatial_shard(
            x.shape[1], shard_spatial=True, stride=stride,
            kernel_size=kernel_size, dilation=dilation,
            offset_bound=offset_bound,
            batch_axes=shard.axes if shard is not None else ())
        shard = None

    from repro.launch.platform import current_platform
    plat = current_platform()

    def _impl():
        if plat == "xla_ref":
            # platform='xla_ref' (launch.platform): the reference rung
            # promoted to a first-class lowering — the same arithmetic
            # as the bounded kernels, emitted as a plain XLA graph (no
            # Pallas at all).  Still dispatched through the hook seam
            # so the obs recorder / tuner time it like any backend.
            return _reference_impl(
                x, offsets, w, kernel_size=kernel_size, stride=stride,
                dilation=dilation, offset_bound=offset_bound,
                precision=precision, x_scale=x_scale, w_scale=w_scale)
        return _deform_conv_impl(
            x, offsets, w, kernel_size=kernel_size, stride=stride,
            dilation=dilation, offset_bound=offset_bound, tile_h=tile_h,
            tile_w=tile_w, tile_c=tile_c, tile_m=tile_m, dataflow=dataflow,
            precision=precision, cores=cores, shard=shard, spatial=spatial,
            x_scale=x_scale, w_scale=w_scale, interpret=interpret,
            dw_flush_every_step=dw_flush_every_step)

    if offset_bound is None:
        # Unbounded gather baseline IS the XLA reference path — there is
        # no lower rung to degrade to.
        return _impl()

    finish = None
    try:
        finish = _consult_dispatch_hook(
            op="deform_conv", precision=precision, dataflow=dataflow,
            shape=tuple(x.shape), offset_bound=offset_bound,
            kernel_size=kernel_size, stride=stride, dilation=dilation,
            m=m, cores=cores, platform=plat,
            spatial_shards=spatial.shards if spatial is not None else 1)
        out = _impl()
        _finish_dispatch(finish, out=out)
        return out
    except Exception as e:  # noqa: BLE001 — bounded-path failure
        _finish_dispatch(finish, error=e)
        def _fallback():
            if precision == "int8":
                from repro.quant.qat import fake_quant_dcl_reference
                return fake_quant_dcl_reference(
                    x, offsets, w, kernel_size=kernel_size, stride=stride,
                    dilation=dilation, offset_bound=offset_bound,
                    x_scale=x_scale, w_scale=w_scale)
            return _plan.reference_forward(
                x, offsets, w, kernel_size=kernel_size, stride=stride,
                dilation=dilation, offset_bound=offset_bound)
        return _degraded(("deform_conv", precision), e, _fallback)


@functools.partial(
    jax.jit,
    static_argnames=("kernel_size", "stride", "dilation", "offset_bound",
                     "tile_h", "tile_w", "tile_c", "tile_m", "emit",
                     "interpret"))
def _deform_conv_chain_impl(x: Array, w: Array, w_offset: Array,
                            b_offset: Array, b_deform: Array | None, *,
                            kernel_size: int, stride: int, dilation: int,
                            offset_bound: float, x_scale, w_scale,
                            w_offset_scale, y_scale,
                            tile_h: int | None, tile_w: int | None,
                            tile_c: int | None, tile_m: int | None,
                            emit: str, interpret: bool | None) -> Array:
    if interpret is None:
        interpret = default_interpret()
    return chain_forward(
        x, w, w_offset, b_offset, b_deform, kernel_size=kernel_size,
        stride=stride, dilation=dilation, offset_bound=offset_bound,
        x_scale=x_scale, w_scale=w_scale, w_offset_scale=w_offset_scale,
        y_scale=y_scale, tile_h=tile_h, tile_w=tile_w, tile_c=tile_c,
        tile_m=tile_m, emit=emit, interpret=interpret)


def deform_conv_chain(x: Array, w: Array, w_offset: Array,
                      b_offset: Array, b_deform: Array | None = None, *,
                      kernel_size: int = 3, stride: int = 1,
                      dilation: int = 1, offset_bound: float,
                      x_scale, w_scale=None, w_offset_scale=None,
                      y_scale=None,
                      tile_h: int | None = None, tile_w: int | None = None,
                      tile_c: int | None = None, tile_m: int | None = None,
                      emit: str = "int8",
                      interpret: bool | None = None) -> Array:
    """One chained int8 DCL layer: fused offset conv + int8 emission.

    x: (N, H, W, C) — int8 values on the ``x_scale`` grid (the previous
    chained layer's emission) or fp32 (the chain head, quantized here
    with ``x_scale``).  w: (K*K, C, M) fp32 deform weights; w_offset:
    (K*K, C, 2*K*K) fp32 offset-conv weights; b_offset/b_deform the
    biases (the deform bias is folded into the requant epilogue —
    int8 emission must quantize ``y + b``, not ``y``).

    Returns (N, Ho, Wo, M) int8 on the ``y_scale`` grid (``emit="int8"``
    — ``y_scale`` is the NEXT layer's activation scale, required) or
    fp32 (``emit="fp32"``, the chain tail).  Offsets never exist in
    HBM: the offset conv runs in-kernel over the staged Eq. 6 band
    (requires ``tile_c == C`` — a clear ``ValueError`` otherwise).
    Training chained models uses the STE reference
    (``repro.quant.qat.fake_quant_dcl_chain_reference``) — this entry
    is the inference datapath.
    """
    # -- validation (always raises; never degraded) -------------------
    if offset_bound is None:
        raise ValueError(
            "deform_conv_chain requires a trained offset_bound — the "
            "fused offset stage exists because Eq. 6 bounds the band")
    if x_scale is None:
        raise ValueError(
            "deform_conv_chain requires x_scale: chained layers exchange "
            "int8 values whose grid must be pinned by calibration "
            "(repro.quant.calibrate — the table's per-layer x_scale)")
    if emit not in ("int8", "fp32"):
        raise ValueError(
            f"unknown emit {emit!r}; expected 'int8' (chained) or 'fp32' "
            f"(chain tail)")
    if emit == "int8" and y_scale is None:
        raise ValueError(
            "emit='int8' requires y_scale (the NEXT layer's activation "
            "scale — the per-channel requant target grid); pass "
            "emit='fp32' for the chain tail instead")
    c = x.shape[-1]
    if tile_c is not None and tile_c != c:
        raise ValueError(
            f"tile_c={tile_c} is incompatible with chaining: the fused "
            f"offset-conv stage needs the whole channel extent staged "
            f"per band (tile_c == C = {c}), since the offsets must be "
            f"complete before the first bilinear sample consumes them — "
            f"pass tile_c=None (or C) for chained layers")

    def _chain_reference():
        # The reference form of the chained layer: the STE chain oracle
        # (same quantization boundaries on the XLA graph), re-quantized
        # onto the emission grid so chained consumers see the same int8
        # plane the kernel would have produced.  Serves BOTH the
        # degradation fallback and the platform='xla_ref' lowering.
        from repro.quant.qat import fake_quant_dcl_chain_reference
        from repro.quant.qtypes import quantize_values

        sx = jnp.asarray(x_scale, jnp.float32)
        xf = (x.astype(jnp.float32) * sx if x.dtype == jnp.int8
              else x)
        y, _ = fake_quant_dcl_chain_reference(
            xf, w, w_offset, b_offset, b_deform,
            kernel_size=kernel_size, stride=stride, dilation=dilation,
            offset_bound=offset_bound, x_scale=x_scale,
            w_scale=w_scale, w_offset_scale=w_offset_scale,
            y_scale=y_scale if emit == "int8" else None)
        if emit == "int8":
            return quantize_values(y, jnp.asarray(y_scale, jnp.float32))
        return y

    from repro.launch.platform import current_platform
    plat = current_platform()

    finish = None
    try:
        finish = _consult_dispatch_hook(
            op="deform_conv_chain", emit=emit, shape=tuple(x.shape),
            offset_bound=offset_bound, kernel_size=kernel_size,
            stride=stride, dilation=dilation, m=w.shape[-1], cores=1,
            platform=plat)
        if plat == "xla_ref":
            out = _chain_reference()
        else:
            out = _deform_conv_chain_impl(
                x, w, w_offset, b_offset, b_deform,
                kernel_size=kernel_size, stride=stride, dilation=dilation,
                offset_bound=offset_bound, x_scale=x_scale,
                w_scale=w_scale, w_offset_scale=w_offset_scale,
                y_scale=y_scale, tile_h=tile_h, tile_w=tile_w,
                tile_c=tile_c, tile_m=tile_m, emit=emit,
                interpret=interpret)
        _finish_dispatch(finish, out=out)
        return out
    except Exception as e:  # noqa: BLE001 — bounded-path failure
        _finish_dispatch(finish, error=e)
        return _degraded(("deform_conv_chain", emit), e, _chain_reference)
