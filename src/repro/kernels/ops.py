"""Public entry points for the Pallas kernels (padding, banding, dispatch).

The dispatch mirrors the paper's co-design argument:

* ``offset_bound`` given (the Eq. 5-trained model) -> the Pallas
  bounded-halo kernels: static HBM->VMEM bands, no irregular HBM access.
* ``offset_bound`` None (the lambda=0 baseline) -> the pure-XLA gather
  path of ``repro.core.deform_conv`` — dynamic gathers from HBM, exactly
  the "irregular DRAM access" regime the paper measures against.

``interpret`` defaults to True off-TPU (this container is CPU-only); on
a real TPU backend it auto-disables.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp

from repro.core.deform_conv import DCLConfig, sample_patches
from .deform_sample import band_geometry, deform_sample_banded
from .deform_conv_fused import deform_conv_fused_banded
from .matmul import matmul  # re-export  # noqa: F401

Array = jax.Array


def default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def tile_weights(w: Array, tile_c: int) -> Array:
    """(K*K, C, M) deform weights -> (C//tile_c, K*K*tile_c, M) blocks
    so the fused kernel's C-step reads one contiguous VMEM block."""
    k2, c, m = w.shape
    assert c % tile_c == 0, (c, tile_c)
    n_c = c // tile_c
    wt = w.reshape(k2, n_c, tile_c, m).transpose(1, 0, 2, 3)
    return wt.reshape(n_c, k2 * tile_c, m)


def _pad_and_band(x: Array, *, kernel_size: int, stride: int, dilation: int,
                  offset_bound: float, tile_h: int,
                  ho: int) -> tuple[Array, int]:
    """Zero-pad x and slice it into overlapping row bands (Eq. 6 dataflow).

    Returns (bands, n_tiles): bands (N, n_tiles, band_h, w_pad, C).  The
    top/left zero padding of ``pad + halo`` (+1 bottom/right for the
    bilinear corner) makes every in-band corner index valid, so the
    kernel needs no masks — the bounded receptive field is the guarantee.
    """
    n, h, w, c = x.shape
    pad = dilation * (kernel_size // 2)
    hb, band_h = band_geometry(kernel_size=kernel_size, stride=stride,
                               dilation=dilation, offset_bound=offset_bound,
                               tile_h=tile_h)
    n_tiles = -(-ho // tile_h)

    p0 = pad + hb
    hp_needed = (n_tiles - 1) * tile_h * stride + band_h
    p1 = max(0, hp_needed - p0 - h)
    # Left pad aligns the kernel's band-local base (ox*S + hb); the +1 is
    # only needed on the right for the bilinear corner x0+1.
    xp = jnp.pad(x, ((0, 0), (p0, p1), (pad + hb, pad + hb + 1), (0, 0)))

    # Overlapping bands via a row gather (the halo duplication the paper
    # pays in BRAM; here it is one strided HBM copy produced by XLA).
    starts = jnp.arange(n_tiles) * (tile_h * stride)
    rows = starts[:, None] + jnp.arange(band_h)[None, :]     # (n_tiles, band_h)
    bands = jnp.take(xp, rows.reshape(-1), axis=1)
    bands = bands.reshape(n, n_tiles, band_h, xp.shape[2], c)
    return bands, n_tiles


def _out_hw(h: int, w: int, *, kernel_size: int, stride: int,
            dilation: int) -> tuple[int, int]:
    pad = dilation * (kernel_size // 2)
    ho = (h + 2 * pad - dilation * (kernel_size - 1) - 1) // stride + 1
    wo = (w + 2 * pad - dilation * (kernel_size - 1) - 1) // stride + 1
    return ho, wo


@functools.partial(
    jax.jit,
    static_argnames=("kernel_size", "stride", "dilation", "offset_bound",
                     "tile_h", "tile_c", "interpret"))
def deform_sample(x: Array, offsets: Array, *, kernel_size: int = 3,
                  stride: int = 1, dilation: int = 1,
                  offset_bound: float | None = None, tile_h: int = 8,
                  tile_c: int | None = None,
                  interpret: bool | None = None) -> Array:
    """Stage 1: bilinear patch sampling.

    x: (N, H, W, C); offsets: (N, Ho, Wo, 2*K*K) raw offset-conv output.
    Returns (N, Ho, Wo, K*K, C).
    """
    n, h, w, c = x.shape
    ho, wo = offsets.shape[1], offsets.shape[2]
    k2 = kernel_size * kernel_size

    if offset_bound is None:
        # Unbounded model: irregular-gather baseline (paper's lambda=0).
        cfg = DCLConfig(in_channels=c, out_channels=1,
                        kernel_size=kernel_size, stride=stride,
                        dilation=dilation)
        return sample_patches(x, offsets.reshape(n, ho, wo, k2, 2), cfg)

    if interpret is None:
        interpret = default_interpret()
    pad_h = (-ho) % tile_h
    if pad_h:
        offsets = jnp.pad(offsets, ((0, 0), (0, pad_h), (0, 0), (0, 0)))
    bands, n_tiles = _pad_and_band(
        x, kernel_size=kernel_size, stride=stride, dilation=dilation,
        offset_bound=offset_bound, tile_h=tile_h, ho=ho + pad_h)
    patches = deform_sample_banded(
        bands, offsets, kernel_size=kernel_size, stride=stride,
        dilation=dilation, offset_bound=offset_bound, tile_h=tile_h,
        tile_c=tile_c, interpret=interpret)
    return patches[:, :ho]


@functools.partial(
    jax.jit,
    static_argnames=("kernel_size", "stride", "dilation", "offset_bound",
                     "tile_h", "tile_c", "tile_m", "interpret"))
def deform_conv(x: Array, offsets: Array, w: Array, *, kernel_size: int = 3,
                stride: int = 1, dilation: int = 1,
                offset_bound: float | None = None, tile_h: int = 8,
                tile_c: int | None = None, tile_m: int | None = None,
                interpret: bool | None = None) -> Array:
    """Fused DCL stage 1+2: y = g(x, o) * w_deform  (Eq. 2).

    x: (N, H, W, C); offsets: (N, Ho, Wo, 2*K*K); w: (K*K, C, M).
    Returns (N, Ho, Wo, M).
    """
    n, h, w_, c = x.shape
    ho, wo = offsets.shape[1], offsets.shape[2]
    k2 = kernel_size * kernel_size

    if offset_bound is None:
        cfg = DCLConfig(in_channels=c, out_channels=w.shape[-1],
                        kernel_size=kernel_size, stride=stride,
                        dilation=dilation)
        patches = sample_patches(x, offsets.reshape(n, ho, wo, k2, 2), cfg)
        y = jnp.einsum("nhwkc,kcm->nhwm", patches, w,
                       preferred_element_type=jnp.float32)
        return y.astype(x.dtype)

    if interpret is None:
        interpret = default_interpret()
    tc = tile_c or c
    pad_h = (-ho) % tile_h
    if pad_h:
        offsets = jnp.pad(offsets, ((0, 0), (0, pad_h), (0, 0), (0, 0)))
    bands, n_tiles = _pad_and_band(
        x, kernel_size=kernel_size, stride=stride, dilation=dilation,
        offset_bound=offset_bound, tile_h=tile_h, ho=ho + pad_h)
    w_tiles = tile_weights(w.astype(x.dtype), tc)
    y = deform_conv_fused_banded(
        bands, offsets, w_tiles, kernel_size=kernel_size, stride=stride,
        dilation=dilation, offset_bound=offset_bound, tile_h=tile_h,
        tile_c=tc, tile_m=tile_m, interpret=interpret)
    return y[:, :ho]
