"""Pallas TPU kernels for the compute hot spots (validated against the
``ref.py`` oracles in interpret mode; TPU is the lowering target):

* ``deform_sample``     — stage-1 bounded-halo bilinear sampling (Eq. 6)
* ``deform_conv_fused`` — stage 1+2 fused in VMEM (beyond-paper)
* ``flash_attention``   — blockwise online-softmax attention
* ``matmul``            — tiled MXU matmul (the systolic-array analogue)

Public entry points live in ``ops``.
"""
