"""Pallas TPU kernels for the compute hot spots (validated against the
``ref.py`` oracles in interpret mode; TPU is the lowering target):

* ``deform_sample``     — stage-1 bounded-halo bilinear sampling (Eq. 6)
* ``deform_conv_fused`` — stage 1+2 fused in VMEM (beyond-paper)
* ``deform_conv_bwd``   — fused backward (d_input / d_offsets /
  d_weights) over the same Eq. 6 bands; wired as a ``jax.custom_vjp``
  on ``ops.deform_conv`` so bounded training never leaves the
  zero-copy dataflow

Both DCL kernels run a zero-copy dataflow by default: the padded input
stays whole in ANY/HBM and each (row-tile, width-tile) Eq. 6 band is
DMA'd into double-buffered VMEM scratch by the kernel itself
(``make_async_copy``), overlapping the next band's fetch with the
current tile's gather + MXU work.  The legacy HBM-materialized banded
dataflow is kept behind ``dataflow="banded"`` as the parity baseline.
* ``flash_attention``   — blockwise online-softmax attention
* ``matmul``            — tiled MXU matmul (the systolic-array analogue)

Public entry points live in ``ops``.
"""
