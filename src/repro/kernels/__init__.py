"""Pallas TPU kernels for the compute hot spots (validated against the
``ref.py`` oracles in interpret mode; TPU is the lowering target).

Every bounded DCL kernel is emitted from the unified band-pipeline
emitter (``band_pipeline.py`` — ``BandSpec``/``DCLPlan`` + the shared
double-buffered ``make_async_copy`` band stager; see
``docs/kernels.md``):

* ``deform_sample``     — stage-1 bounded-halo bilinear sampling
  (Eq. 6; a contraction-free plan)
* ``deform_conv_fused`` — stage 1+2 fused in VMEM (fp32 plan)
* ``deform_conv_q``     — the int8 plans: fused dequant inference and
  the int8→int8 *chained* kernel (fused in-kernel offset-conv stage +
  per-channel requant emission — back-to-back DCLs never round-trip
  fp32 through HBM)
* ``deform_conv_bwd``   — fused backward (d_input / d_offsets /
  d_weights) over the same Eq. 6 bands via the shared stager, with the
  Megacore ``cores`` grid axis; wired as a ``jax.custom_vjp`` on
  ``ops.deform_conv`` so bounded training never leaves the zero-copy
  dataflow

The zero-copy dataflow is the default: the padded input stays whole in
ANY/HBM and each (row-tile, width-tile) Eq. 6 band is DMA'd into
double-buffered VMEM scratch by the kernel itself, overlapping the next
band's fetch with the current tile's gather + MXU work.  The legacy
HBM-materialized banded dataflow is kept behind ``dataflow="banded"``
as the parity baseline.

* ``flash_attention``   — blockwise online-softmax attention
* ``matmul``            — tiled MXU matmul (the systolic-array analogue)

Public entry points live in ``ops``; plan building and the runner
bodies in ``plan``.
"""
