"""int8 zero-copy fused DCL kernel (quantized datapath).

The paper's accelerator computes in fixed point; the TPU analogue is an
int8 band dataflow: the Eq. 6 geometry is dtype-independent, but at
1 byte/elem every VMEM byte holds 4x more of the offset band than fp32,
so the Sec. 3.2 chooser (``tiling.choose_kernel_tiles(dtype="int8")``)
runs wider tiles at the same budget and the modeled HBM input traffic
drops ~4x (gated >= 3x in ``tests/test_quant.py``).

Precision split (CoDeNet / Xu et al. 2021 — deformable conv tolerates
8-bit weights/activations when interpolation stays high precision):

* the **band DMA** streams symmetric-int8 activations HBM -> VMEM
  through the same double-buffered ``make_async_copy`` pipeline as the
  fp32 kernel (``make_band_dma`` — one geometry, two dtypes);
* **bilinear coefficients are fp32**: corner indices/fractions come
  from the shared ``corner_geometry`` (address generation is always
  full precision), the int8 corner values combine in fp32, and the
  result is re-rounded onto the activation grid.  A bilinear mix is
  convex, so the combination of in-range int8 values is in range —
  requantization is a pure round, never a clip, and the patch scale is
  exactly the activation scale;
* the **MXU contraction runs int8 x int8 -> int32** (exact
  accumulation, no fp32 rounding inside the reduction);
* a **fused dequant epilogue** rescales the int32 accumulator by the
  per-output-channel combined scale ``s_x * s_w[m]`` and emits fp32 —
  the quantized tensor never round-trips HBM.

Quantization/padding commute because the grid is symmetric (0 -> 0),
so ``ops._pad_zerocopy`` pads the int8 plane directly.  This kernel is
the *inference* datapath; training uses the fake-quant QAT wrappers of
``repro.quant.qat`` through the fp32 custom-VJP kernels.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ._compat import tpu_compiler_params
from .deform_sample import (N_BUFFERS, band_geometry, corner_geometry,
                            make_band_dma)

Array = jax.Array


def _bilinear_int8_from_band(band, off, *, kernel_size: int, stride: int,
                             dilation: int, offset_bound: float,
                             tile_h: int, wo: int):
    """Sample an int8 VMEM band with fp32 coefficients -> int8 patches.

    band: (band_h, w_pad, tc) int8; off: (tile_h, wo, K*K, 2) raw.
    Returns (tile_h*wo*K*K, tc) int8 — integer values on the activation
    grid (the convex bilinear mix of int8 values stays in [-127, 127]).
    """
    k2 = kernel_size * kernel_size
    band_h, w_pad, tc = band.shape
    y0, x0, ty, tx = corner_geometry(
        off, kernel_size=kernel_size, stride=stride, dilation=dilation,
        offset_bound=offset_bound, tile_h=tile_h, wo=wo)

    flat = band.reshape(band_h * w_pad, tc)
    p = tile_h * wo * k2
    idx00 = (y0 * w_pad + x0).reshape(p)
    ty = ty.reshape(p, 1)
    tx = tx.reshape(p, 1)

    def gat(idx):
        return jnp.take(flat, idx, axis=0).astype(jnp.float32)

    # Same corner order + accumulation order as the fp32 kernel, so the
    # pre-round fp32 values match ``_bilinear_from_band`` bit-for-bit.
    out = gat(idx00) * ((1 - ty) * (1 - tx))
    out += gat(idx00 + 1) * ((1 - ty) * tx)
    out += gat(idx00 + w_pad) * (ty * (1 - tx))
    out += gat(idx00 + w_pad + 1) * (ty * tx)
    return jnp.round(out).astype(jnp.int8)


def _fused_zerocopy_q_kernel(x_hbm, off_ref, w_ref, scale_ref, out_ref,
                             band_ref, acc_ref, sem_ref, *,
                             kernel_size: int, stride: int, dilation: int,
                             offset_bound: float, tile_h: int, tile_w: int,
                             band_h: int, band_w: int, tile_c: int):
    k2 = kernel_size * kernel_size
    i = pl.program_id(0)
    j = pl.program_id(1)
    ww = pl.program_id(2)
    cc = pl.program_id(4)
    c_steps = pl.num_programs(4)

    def dma(step, slot):
        return make_band_dma(
            x_hbm, band_ref, sem_ref, batch=i,
            row0=j * (tile_h * stride), col0=ww * (tile_w * stride),
            c0=step * tile_c, band_h=band_h, band_w=band_w,
            tile_c=tile_c, slot=slot)

    @pl.when(cc == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        dma(0, 0).start()

    @pl.when(cc + 1 < c_steps)
    def _prefetch():
        dma(cc + 1, (cc + 1) % N_BUFFERS).start()

    dma(cc, cc % N_BUFFERS).wait()

    off = off_ref[0].reshape(tile_h, tile_w, k2, 2)
    patches_q = _bilinear_int8_from_band(
        band_ref[cc % N_BUFFERS], off, kernel_size=kernel_size,
        stride=stride, dilation=dilation, offset_bound=offset_bound,
        tile_h=tile_h, wo=tile_w)
    # (th*tw, k2*tc) int8 @ (k2*tc, tm) int8 -> int32 on the MXU.
    lhs = patches_q.reshape(tile_h * tile_w, k2 * tile_c)
    acc_ref[...] += jnp.dot(lhs, w_ref[0],
                            preferred_element_type=jnp.int32)

    @pl.when(cc == c_steps - 1)
    def _dequant_flush():
        tm = out_ref.shape[-1]
        y = acc_ref[...].astype(jnp.float32) * scale_ref[0]
        out_ref[0] = y.reshape(tile_h, tile_w, tm).astype(out_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("kernel_size", "stride", "dilation", "offset_bound",
                     "tile_h", "tile_w", "tile_c", "tile_m", "interpret"))
def deform_conv_fused_zerocopy_q(x_pad_q: Array, offsets: Array,
                                 w_tiles_q: Array, scale: Array, *,
                                 kernel_size: int, stride: int,
                                 dilation: int, offset_bound: float,
                                 tile_h: int, tile_w: int,
                                 tile_c: int | None = None,
                                 tile_m: int | None = None,
                                 interpret: bool = True) -> Array:
    """int8 fused DCL over the whole padded input (zero-copy dataflow).

    x_pad_q:   (N, Hp, Wp, C) int8 zero-padded input, whole in ANY/HBM
    offsets:   (N, Ho, Wo, 2*K*K) fp32 raw offsets (full precision)
    w_tiles_q: (C//tile_c, K*K*tile_c, M) int8 ``ops.tile_weights`` layout
    scale:     (1, M) fp32 combined dequant scale ``s_x * s_w[m]``
    returns:   (N, Ho, Wo, M) fp32 (dequantized by the fused epilogue)
    """
    n, hp, wp, c = x_pad_q.shape
    _, ho, wo, _ = offsets.shape
    assert x_pad_q.dtype == jnp.int8, x_pad_q.dtype
    assert w_tiles_q.dtype == jnp.int8, w_tiles_q.dtype
    assert ho % tile_h == 0 and wo % tile_w == 0, (ho, wo, tile_h, tile_w)
    h_tiles, w_tiles_n = ho // tile_h, wo // tile_w
    k2 = kernel_size * kernel_size
    tc = tile_c or c
    assert c % tc == 0
    c_steps = c // tc
    assert w_tiles_q.shape[0] == c_steps and w_tiles_q.shape[1] == k2 * tc
    m = w_tiles_q.shape[2]
    tm = tile_m or m
    assert m % tm == 0
    assert scale.shape == (1, m), scale.shape
    _, band_h = band_geometry(kernel_size=kernel_size, stride=stride,
                              dilation=dilation, offset_bound=offset_bound,
                              tile_h=tile_h)
    _, band_w = band_geometry(kernel_size=kernel_size, stride=stride,
                              dilation=dilation, offset_bound=offset_bound,
                              tile_h=tile_w)
    assert (h_tiles - 1) * tile_h * stride + band_h <= hp, "underpadded H"
    assert (w_tiles_n - 1) * tile_w * stride + band_w <= wp, "underpadded W"

    return pl.pallas_call(
        functools.partial(
            _fused_zerocopy_q_kernel, kernel_size=kernel_size,
            stride=stride, dilation=dilation, offset_bound=offset_bound,
            tile_h=tile_h, tile_w=tile_w, band_h=band_h, band_w=band_w,
            tile_c=tc),
        grid=(n, h_tiles, w_tiles_n, m // tm, c_steps),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.ANY),      # whole int8 input
            pl.BlockSpec((1, tile_h, tile_w, 2 * k2),
                         lambda i, j, ww, mm, cc: (i, j, ww, 0)),
            pl.BlockSpec((1, k2 * tc, tm),
                         lambda i, j, ww, mm, cc: (cc, 0, mm)),
            pl.BlockSpec((1, tm),
                         lambda i, j, ww, mm, cc: (0, mm)),
        ],
        out_specs=pl.BlockSpec((1, tile_h, tile_w, tm),
                               lambda i, j, ww, mm, cc: (i, j, ww, mm)),
        out_shape=jax.ShapeDtypeStruct((n, ho, wo, m), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((N_BUFFERS, band_h, band_w, tc), jnp.int8),
            pltpu.VMEM((tile_h * tile_w, tm), jnp.int32),
            pltpu.SemaphoreType.DMA((N_BUFFERS,)),
        ],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary", "arbitrary")),
        interpret=interpret,
    )(x_pad_q, offsets, w_tiles_q, scale)
