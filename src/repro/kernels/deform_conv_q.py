"""int8 zero-copy fused DCL kernels (quantized datapath + layer chaining).

The paper's accelerator computes in fixed point; the TPU analogue is an
int8 band dataflow: the Eq. 6 geometry is dtype-independent, but at
1 byte/elem every VMEM byte holds 4x more of the offset band than fp32,
so the Sec. 3.2 chooser (``tiling.choose_kernel_tiles(dtype="int8")``)
runs wider tiles at the same budget and the modeled HBM input traffic
drops ~4x (gated >= 3x in ``tests/test_quant.py``).

Precision split (CoDeNet / Xu et al. 2021 — deformable conv tolerates
8-bit weights/activations when interpolation stays high precision):

* the **band DMA** streams symmetric-int8 activations HBM -> VMEM
  through the same double-buffered pipeline as the fp32 kernel
  (``band_pipeline.BandStager`` — one geometry, two dtypes);
* **bilinear coefficients are fp32**: corner indices/fractions come
  from the shared ``corner_geometry`` (address generation is always
  full precision), the int8 corner values combine in fp32, and the
  result is re-rounded onto the activation grid.  A bilinear mix is
  convex, so the combination of in-range int8 values is in range —
  requantization is a pure round, never a clip, and the patch scale is
  exactly the activation scale;
* the **MXU contraction runs int8 x int8 -> int32** (exact
  accumulation, no fp32 rounding inside the reduction);
* the epilogue is plan-selected: a **fused dequant**
  (``deform_conv_fused_zerocopy_q`` — rescale by the per-output-channel
  ``s_x * s_w[m]``, emit fp32) or a **fused requant**
  (``deform_conv_fused_zerocopy_chain`` — rescale by
  ``s_x * s_w[m] / s_y`` with the bias folded as ``b[m] / s_y``, round,
  clip, emit int8 on the next layer's activation grid).  Either way the
  quantized tensor never round-trips HBM at fp32.

The chain kernel additionally fuses the **offset-conv stage**
(``band_pipeline.offset_conv_stage``): the offset conv's undeformed
taps are a static-index subset of the staged Eq. 6 band, so the raw
offsets are produced in-kernel from the int8 band + quantized offset
weights — no separate fp32 offset pass and no offsets in HBM at all.

Both kernels are emitted by ``band_pipeline.forward_call``; this module
only builds their ``DCLPlan``s.  Quantization/padding commute because
the grid is symmetric (0 -> 0), so the padded int8 plane needs no
special casing.  These are *inference* datapaths; training uses the
fake-quant QAT/chain wrappers of ``repro.quant.qat`` (STE) through the
fp32 custom-VJP kernels.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .band_pipeline import (  # noqa: F401  (re-export)
    BandSpec, DCLPlan, _bilinear_int8_from_band, forward_call)

Array = jax.Array


def _int8_plan(*, kernel_size: int, stride: int, dilation: int,
               offset_bound: float, tile_h: int, tile_w: int, tile_c: int,
               tile_m: int, epilogue: str, fuse_offsets: bool) -> DCLPlan:
    return DCLPlan(
        band=BandSpec(kernel_size=kernel_size, stride=stride,
                      dilation=dilation, offset_bound=offset_bound,
                      tile_h=tile_h, tile_w=tile_w),
        tile_c=tile_c, tile_m=tile_m, band_dtype="int8", acc_dtype="int32",
        epilogue=epilogue, fuse_offsets=fuse_offsets)


@functools.partial(
    jax.jit,
    static_argnames=("kernel_size", "stride", "dilation", "offset_bound",
                     "tile_h", "tile_w", "tile_c", "tile_m", "interpret"))
def deform_conv_fused_zerocopy_q(x_pad_q: Array, offsets: Array,
                                 w_tiles_q: Array, scale: Array, *,
                                 kernel_size: int, stride: int,
                                 dilation: int, offset_bound: float,
                                 tile_h: int, tile_w: int,
                                 tile_c: int | None = None,
                                 tile_m: int | None = None,
                                 interpret: bool = True) -> Array:
    """int8 fused DCL over the whole padded input (zero-copy dataflow).

    x_pad_q:   (N, Hp, Wp, C) int8 zero-padded input, whole in ANY/HBM
    offsets:   (N, Ho, Wo, 2*K*K) fp32 raw offsets (full precision)
    w_tiles_q: (C//tile_c, K*K*tile_c, M) int8 ``plan.tile_weights`` layout
    scale:     (1, M) fp32 combined dequant scale ``s_x * s_w[m]``
    returns:   (N, Ho, Wo, M) fp32 (dequantized by the fused epilogue)
    """
    assert x_pad_q.dtype == jnp.int8, x_pad_q.dtype
    assert w_tiles_q.dtype == jnp.int8, w_tiles_q.dtype
    c = x_pad_q.shape[-1]
    m = w_tiles_q.shape[2]
    plan = _int8_plan(kernel_size=kernel_size, stride=stride,
                      dilation=dilation, offset_bound=offset_bound,
                      tile_h=tile_h, tile_w=tile_w, tile_c=tile_c or c,
                      tile_m=tile_m or m, epilogue="dequant",
                      fuse_offsets=False)
    return forward_call(plan, x_pad_q, offsets, w_tiles_q, scale=scale,
                        out_dtype=jnp.float32, interpret=interpret)


@functools.partial(
    jax.jit,
    static_argnames=("kernel_size", "stride", "dilation", "offset_bound",
                     "tile_h", "tile_w", "tile_m", "emit", "ho", "wo",
                     "interpret"))
def deform_conv_fused_zerocopy_chain(x_pad_q: Array, w_tiles_q: Array,
                                     woff_tiles_q: Array, off_scale: Array,
                                     off_bias: Array, out_scale: Array,
                                     out_bias: Array, *, kernel_size: int,
                                     stride: int, dilation: int,
                                     offset_bound: float, tile_h: int,
                                     tile_w: int, tile_m: int | None = None,
                                     emit: str = "int8", ho: int, wo: int,
                                     interpret: bool = True) -> Array:
    """Chained int8 DCL: fused offset-conv stage + int8 output emission.

    x_pad_q:      (N, Hp, Wp, C) int8 zero-padded input (the previous
                  chained layer's emission, or the chain head quantized
                  once) — the whole C extent is staged per band
                  (``tile_c = C``, required by the fused offset stage)
    w_tiles_q:    (1, K*K*C, M) int8 deform weights
    woff_tiles_q: (1, K*K*C, 2*K*K) int8 offset-conv weights
    off_scale:    (1, 2*K*K) fp32 ``s_x * s_woff`` dequant scales
    off_bias:     (1, 2*K*K) fp32 offset-conv bias
    out_scale:    (1, M) fp32 — ``s_x * s_w[m] / s_y`` (``emit="int8"``,
                  the per-channel requant onto the next layer's grid) or
                  ``s_x * s_w[m]`` (``emit="fp32"``, the chain tail)
    out_bias:     (1, M) fp32 — ``b[m] / s_y`` resp. ``b[m]``
    returns:      (N, ho, wo, M) int8 on the ``s_y`` grid, or fp32
    """
    assert x_pad_q.dtype == jnp.int8, x_pad_q.dtype
    assert w_tiles_q.dtype == jnp.int8, w_tiles_q.dtype
    assert woff_tiles_q.dtype == jnp.int8, woff_tiles_q.dtype
    if emit not in ("int8", "fp32"):
        raise ValueError(f"unknown emit {emit!r}; expected 'int8' or 'fp32'")
    c = x_pad_q.shape[-1]
    m = w_tiles_q.shape[2]
    plan = _int8_plan(kernel_size=kernel_size, stride=stride,
                      dilation=dilation, offset_bound=offset_bound,
                      tile_h=tile_h, tile_w=tile_w, tile_c=c,
                      tile_m=tile_m or m,
                      epilogue="requant" if emit == "int8" else "dequant",
                      fuse_offsets=True)
    return forward_call(plan, x_pad_q, None, w_tiles_q, scale=out_scale,
                        bias=out_bias, woff_tiles=woff_tiles_q,
                        off_scale=off_scale, off_bias=off_bias, ho=ho, wo=wo,
                        out_dtype=jnp.int8 if emit == "int8" else jnp.float32,
                        interpret=interpret)
