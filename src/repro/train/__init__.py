from .trainer import NonFiniteDivergence, Trainer, TrainerConfig  # noqa: F401
