"""Fault-tolerant training loop.

Production posture:

* params/optimizer sharded by the logical rules (FSDP + TP);
* batches laid out data-parallel on the mesh's 'batch' axes before the
  step, so the shard_map DCL kernel path (PR 4) consumes local shards
  with no resharding;
* gradient accumulation over microbatches (scan inside jit);
* optional int8 error-feedback gradient compression;
* checkpoint every ``ckpt_every`` steps (async, atomic, keep-k, CRC);
* auto-resume from the latest complete checkpoint, resharded onto the
  restart mesh (``CheckpointManager.restore(shardings=...)`` — elastic);
* numerics sentinels: loss/grad-norm finiteness is checked INSIDE the
  jitted step and a non-finite step is a no-op on the state
  (``jnp.where``-selected — buffer donation forbids keeping the old
  state outside), logged, and counted; ``max_skips`` consecutive
  non-finite steps raise :class:`NonFiniteDivergence` (retrying a
  divergence from the same checkpoint replays the same divergence);
* failure handling: a step that raises is retried from the last
  checkpoint with exponential backoff (restore + data replay — the
  pipeline is stateless, so the replay is bit-exact);
* preemption: SIGTERM flips a flag; the loop checks it each step and
  performs a save-and-exit instead of dying mid-step.

``fault_hook(step)`` / ``batch_hook(step, batch)`` are the chaos seams
(``repro.resilience.ChaosHooks``): the first may raise before a step,
the second may transform (poison) the host batch.  Health telemetry —
``skipped`` / ``recovered`` / ``retries`` / ``preempted`` — accumulates
in ``Trainer.telemetry`` and is appended to ``history`` when the run
ends; per-step ``grad_norm`` rides in the logged history entries.
"""
from __future__ import annotations

import dataclasses
import signal
import statistics
import time
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.checkpoint import CheckpointManager
from repro.distributed.compression import ef_compress_grads, init_ef_state
from repro.distributed.sharding import named_sharding, use_rules
from repro.obs import MetricsRegistry, Tracer
from repro.obs import trace as _trace
from repro.optim import Optimizer, opt_state_specs
from repro.optim.optimizers import global_norm

Array = jax.Array


class NonFiniteDivergence(RuntimeError):
    """Training diverged: ``max_skips`` consecutive non-finite steps.

    Deliberately NOT retried by the node-failure path — the data
    pipeline is stateless, so restore-and-replay would reproduce the
    same non-finite batch forever.
    """


@dataclasses.dataclass
class TrainerConfig:
    total_steps: int = 100
    ckpt_every: int = 20
    ckpt_dir: str = "/tmp/repro_ckpt"
    keep: int = 3
    microbatches: int = 1          # gradient accumulation factor
    grad_compression: str | None = None   # None | 'int8_ef'
    log_every: int = 10
    max_retries: int = 3
    max_skips: int = 3             # consecutive non-finite steps -> raise
    retry_backoff: float = 0.0     # seconds; doubles per consecutive retry


class Trainer:
    def __init__(self, *, loss_fn: Callable[[Any, Any], tuple[Array, dict]],
                 params: Any, optimizer: Optimizer, mesh,
                 param_specs: Any, batch_fn: Callable[[int], Any],
                 config: TrainerConfig,
                 fault_hook: Callable[[int], None] | None = None,
                 batch_hook: Callable[[int, Any], Any] | None = None,
                 clock: Callable[[], float] = time.monotonic,
                 sleep: Callable[[float], None] | None = None,
                 registry: MetricsRegistry | None = None,
                 tracer: Tracer | None = None):
        self.cfg = config
        self.mesh = mesh
        self.opt = optimizer
        self.batch_fn = batch_fn
        self.fault_hook = fault_hook
        self.batch_hook = batch_hook
        self.clock = clock
        self._sleep = sleep
        self.ckpt = CheckpointManager(config.ckpt_dir, keep=config.keep)
        self.history: list[dict] = []
        # Observability (ISSUE 8): health telemetry lives in a metrics
        # registry; ``Trainer.telemetry`` is a read-only view with the
        # pre-obs dict shape.  Per-trainer registry by default.
        self.metrics = registry if registry is not None else MetricsRegistry()
        self._tracer = tracer
        m = self.metrics
        self._c_skipped = m.counter(
            "train_steps_skipped_total", "non-finite steps skipped")
        self._c_recovered = m.counter(
            "train_recovered_total", "restore-and-replay recoveries")
        self._c_retries = m.counter(
            "train_retries_total", "step failures retried")
        self._g_preempted = m.gauge(
            "train_preempted", "1 after a SIGTERM save-and-exit")
        self._h_step = m.histogram(
            "train_step_seconds", "wall time per completed training step")
        self._preempted = False
        # Wall time of every completed step (not just logged ones) —
        # feeds the §Training-throughput comparison of EXPERIMENTS.md
        # (XLA-reference vs Pallas-kernel-path DCL training).
        self.step_seconds: list[float] = []

        with use_rules(mesh=mesh):
            self.param_specs = param_specs
            self.params = jax.device_put(
                params, self._named(param_specs)) if mesh else params
            self.opt_state = optimizer.init(self.params)
            self.ef_state = (init_ef_state(self.params)
                             if config.grad_compression == "int8_ef" else None)
        self.step = 0
        self._build_step(loss_fn)

    @property
    def _tr(self) -> Tracer:
        return self._tracer if self._tracer is not None \
            else _trace.get_tracer()

    @property
    def telemetry(self) -> dict:
        """Health telemetry view, rendered FROM the metrics registry —
        the exact dict the pre-obs trainer accumulated by hand."""
        return {"skipped": int(self._c_skipped.value()),
                "recovered": int(self._c_recovered.value()),
                "retries": int(self._c_retries.value()),
                "preempted": bool(self._g_preempted.value())}

    def _named(self, spec_tree):
        return jax.tree_util.tree_map(
            lambda s: NamedSharding(self.mesh, s), spec_tree,
            is_leaf=lambda x: isinstance(x, P))

    def _build_step(self, loss_fn):
        cfg = self.cfg
        opt = self.opt
        use_ef = cfg.grad_compression == "int8_ef"

        def one_step(params, opt_state, ef_state, step, batch):
            if cfg.microbatches > 1:
                def micro(carry, mb):
                    acc, = carry
                    (loss, _), g = jax.value_and_grad(
                        loss_fn, has_aux=True)(params, mb)
                    acc = jax.tree_util.tree_map(
                        lambda a, b: a + b.astype(jnp.float32), acc, g)
                    return (acc,), loss
                zeros = jax.tree_util.tree_map(
                    lambda p: jnp.zeros(p.shape, jnp.float32), params)
                (gsum,), losses = jax.lax.scan(micro, (zeros,), batch)
                grads = jax.tree_util.tree_map(
                    lambda g: g / cfg.microbatches, gsum)
                loss = jnp.mean(losses)
            else:
                (loss, _), grads = jax.value_and_grad(
                    loss_fn, has_aux=True)(params, batch)
            grad_norm = global_norm(grads)
            finite = jnp.isfinite(loss) & jnp.isfinite(grad_norm)
            if use_ef:
                grads, new_ef = ef_compress_grads(grads, ef_state)
            new_params, new_opt = opt.update(grads, opt_state, params, step)
            # Sentinel select INSIDE jit: the inputs are donated, so
            # "keep the old state" must be expressed as data flow —
            # a non-finite step is a no-op on every state leaf.
            keep = lambda new, old: jax.tree_util.tree_map(
                lambda a, b: jnp.where(finite, a, b), new, old)
            new_params = keep(new_params, params)
            new_opt = keep(new_opt, opt_state)
            if use_ef:
                ef_state = keep(new_ef, ef_state)
            return new_params, new_opt, ef_state, loss, grad_norm, finite

        self._jit_step = jax.jit(one_step, donate_argnums=(0, 1, 2))

    # -- checkpoint state bundle -------------------------------------
    def _bundle(self):
        return {"params": self.params, "opt": self.opt_state,
                "ef": self.ef_state, "step": jnp.asarray(self.step)}

    def _bundle_shardings(self):
        """NamedShardings for the full checkpoint bundle on the CURRENT
        mesh — params by their specs, optimizer state by
        ``opt_state_specs`` (slot buffers shard like their params), the
        error-feedback buffers likewise, the step scalar replicated.
        This is what makes ``try_resume`` elastic: the restore lays the
        state out for whatever mesh the restart sees."""
        if self.mesh is None:
            return None
        specs = {"params": self.param_specs,
                 "opt": opt_state_specs(self.opt, self.param_specs),
                 "ef": (self.param_specs if self.ef_state is not None
                        else None),
                 "step": P()}
        return self._named(specs)

    def save(self):
        with self._tr.span("train/checkpoint", step=self.step):
            self.ckpt.save(self.step, self._bundle())

    def try_resume(self) -> bool:
        last = self.ckpt.latest_step()
        if last is None:
            return False
        restored, step = self.ckpt.restore(
            self._bundle(), shardings=self._bundle_shardings())
        self.params = restored["params"]
        self.opt_state = restored["opt"]
        self.ef_state = restored["ef"]
        self.step = int(restored["step"])
        return True

    @property
    def last_loss(self) -> float:
        """Most recent logged loss.  ``history[-1]`` is no longer a loss
        entry in general — event records (skips, recoveries, the final
        ``health`` summary) interleave with the logged steps."""
        for h in reversed(self.history):
            if "loss" in h:
                return h["loss"]
        return float("nan")

    def median_step_sec(self, *, skip_first: int = 1) -> float:
        """Median wall time per completed step, excluding the first
        ``skip_first`` steps (compilation).  nan if nothing completed."""
        ts = self.step_seconds[skip_first:]
        if not ts:
            return float("nan")
        return statistics.median(ts)

    # -- main loop ----------------------------------------------------
    def _device_batch(self, step: int):
        batch = self.batch_fn(step)
        if self.batch_hook is not None:
            batch = self.batch_hook(step, batch)
        if self.cfg.microbatches > 1:
            batch = jax.tree_util.tree_map(
                lambda x: np.reshape(
                    np.asarray(x),
                    (self.cfg.microbatches,
                     x.shape[0] // self.cfg.microbatches) + x.shape[1:]),
                batch)
        return self._shard_batch(batch)

    def _shard_batch(self, batch):
        """Lay the host batch out data-parallel before the step: the
        sample axis is placed on the mesh's 'batch' logical axes (PR 4
        — the shard_map DCL kernel path then consumes its local shard
        with no resharding; non-dividing batches fall back to
        replication via the logical-rules divisibility check).  The
        sample axis is axis 1 under gradient accumulation (axis 0 is
        the microbatch scan)."""
        if self.mesh is None:
            return batch
        axis = 1 if self.cfg.microbatches > 1 else 0

        def put(x):
            x = jnp.asarray(x)
            if x.ndim <= axis:
                return x
            axes = [None] * x.ndim
            axes[axis] = "batch"
            return jax.device_put(
                x, named_sharding(self.mesh, x.shape, axes))
        return jax.tree_util.tree_map(put, batch)

    def _on_sigterm(self, signum, frame):
        self._preempted = True

    def _preempt_exit(self):
        self.save()
        self.ckpt.wait()
        self._g_preempted.set(1)
        self._tr.event("train/preempt", step=self.step)
        self.history.append(
            {"step": self.step,
             "event": f"preempted: checkpoint saved at step {self.step}, "
                      f"exiting"})
        self.history.append({"step": self.step, "event": "health",
                             **self.telemetry})
        return self.history

    def run(self) -> list[dict]:
        cfg = self.cfg
        retries = 0
        skips = 0
        prev_handler = None
        try:
            prev_handler = signal.signal(signal.SIGTERM, self._on_sigterm)
        except ValueError:
            pass          # not the main thread (tests, notebook executors)
        try:
            with use_rules(mesh=self.mesh):
                while self.step < cfg.total_steps:
                    if self._preempted:
                        return self._preempt_exit()
                    try:
                        if self.fault_hook is not None:
                            self.fault_hook(self.step)
                        with self._tr.span("train/step",
                                           step=self.step) as step_span:
                            with self._tr.span("train/data",
                                               step=self.step):
                                batch = self._device_batch(self.step)
                            t0 = self.clock()
                            with self._tr.span("train/compute",
                                               step=self.step):
                                (self.params, self.opt_state, self.ef_state,
                                 loss, grad_norm, finite) = self._jit_step(
                                    self.params, self.opt_state,
                                    self.ef_state, jnp.asarray(self.step),
                                    batch)
                                # float() blocks on the device values, so
                                # dt covers the computation, not dispatch.
                                loss = float(loss)
                                grad_norm = float(grad_norm)
                            dt = self.clock() - t0
                            step_span.set_attr(finite=bool(finite))
                        if bool(finite):
                            skips = 0
                            self.step_seconds.append(dt)
                            self._h_step.observe(dt)
                            if self.step % cfg.log_every == 0:
                                self.history.append(
                                    {"step": self.step, "loss": loss,
                                     "grad_norm": round(grad_norm, 6),
                                     "sec": round(dt, 4)})
                        else:
                            skips += 1
                            self._c_skipped.inc()
                            self._tr.event("train/skip", step=self.step,
                                           loss=loss, grad_norm=grad_norm)
                            self.history.append(
                                {"step": self.step,
                                 "event": f"skipped: non-finite step "
                                          f"(loss={loss}, "
                                          f"grad_norm={grad_norm})"})
                            if skips >= cfg.max_skips:
                                raise NonFiniteDivergence(
                                    f"{skips} consecutive non-finite steps "
                                    f"(max_skips={cfg.max_skips}) at step "
                                    f"{self.step}; last loss={loss}, "
                                    f"grad_norm={grad_norm} — the replay "
                                    f"is deterministic, so this is a "
                                    f"divergence, not a transient")
                        # A skipped step still advances: the pipeline is
                        # stateless per step, so re-running the same step
                        # would re-poison deterministically.
                        self.step += 1
                        retries = 0
                        if self.step % cfg.ckpt_every == 0:
                            self.save()
                    except (KeyboardInterrupt, NonFiniteDivergence):
                        raise
                    except Exception as e:  # noqa: BLE001 — node failures
                        retries += 1
                        self._c_retries.inc()
                        self._tr.event("train/retry", step=self.step,
                                       attempt=retries,
                                       error=f"{type(e).__name__}: {e}")
                        if retries > cfg.max_retries:
                            raise
                        if cfg.retry_backoff > 0:
                            # resolve time.sleep at call time when not
                            # injected, so monkeypatching the module's
                            # time.sleep still intercepts the backoff
                            (self._sleep or time.sleep)(
                                cfg.retry_backoff * (2 ** (retries - 1)))
                        # Restore-and-replay: stateless data pipeline
                        # makes the retried steps bit-exact.
                        if not self.try_resume():
                            # no checkpoint yet: nothing to restart from
                            raise
                        self._c_recovered.inc()
                        self._tr.event("train/restore", step=self.step)
                        self.history.append(
                            {"step": self.step, "event": f"recovered: {e}"})
                if self._preempted:
                    return self._preempt_exit()
                self.save()
                self.ckpt.wait()
                self.history.append({"step": self.step, "event": "health",
                                     **self.telemetry})
        finally:
            if prev_handler is not None:
                signal.signal(signal.SIGTERM, prev_handler)
        return self.history
