"""Fault-tolerant training loop.

Production posture:

* params/optimizer sharded by the logical rules (FSDP + TP);
* batches laid out data-parallel on the mesh's 'batch' axes before the
  step, so the shard_map DCL kernel path (PR 4) consumes local shards
  with no resharding;
* gradient accumulation over microbatches (scan inside jit);
* optional int8 error-feedback gradient compression;
* checkpoint every ``ckpt_every`` steps (async, atomic, keep-k);
* auto-resume from the latest complete checkpoint;
* failure handling: a step that raises is retried from the last
  checkpoint (restore + data replay — the pipeline is stateless, so the
  replay is bit-exact);
* straggler/elasticity: restore reshards onto whatever mesh the restart
  sees (``CheckpointManager.restore(shardings=...)``).

``fault_hook`` injects failures for the integration tests.
"""
from __future__ import annotations

import dataclasses
import statistics
import time
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.checkpoint import CheckpointManager
from repro.distributed.compression import ef_compress_grads, init_ef_state
from repro.distributed.sharding import named_sharding, use_rules
from repro.optim import Optimizer

Array = jax.Array


@dataclasses.dataclass
class TrainerConfig:
    total_steps: int = 100
    ckpt_every: int = 20
    ckpt_dir: str = "/tmp/repro_ckpt"
    keep: int = 3
    microbatches: int = 1          # gradient accumulation factor
    grad_compression: str | None = None   # None | 'int8_ef'
    log_every: int = 10
    max_retries: int = 3


class Trainer:
    def __init__(self, *, loss_fn: Callable[[Any, Any], tuple[Array, dict]],
                 params: Any, optimizer: Optimizer, mesh,
                 param_specs: Any, batch_fn: Callable[[int], Any],
                 config: TrainerConfig,
                 fault_hook: Callable[[int], None] | None = None):
        self.cfg = config
        self.mesh = mesh
        self.opt = optimizer
        self.batch_fn = batch_fn
        self.fault_hook = fault_hook
        self.ckpt = CheckpointManager(config.ckpt_dir, keep=config.keep)
        self.history: list[dict] = []
        # Wall time of every completed step (not just logged ones) —
        # feeds the §Training-throughput comparison of EXPERIMENTS.md
        # (XLA-reference vs Pallas-kernel-path DCL training).
        self.step_seconds: list[float] = []

        with use_rules(mesh=mesh):
            self.param_specs = param_specs
            self.params = jax.device_put(
                params, self._named(param_specs)) if mesh else params
            self.opt_state = optimizer.init(self.params)
            self.ef_state = (init_ef_state(self.params)
                             if config.grad_compression == "int8_ef" else None)
        self.step = 0
        self._build_step(loss_fn)

    def _named(self, spec_tree):
        return jax.tree_util.tree_map(
            lambda s: NamedSharding(self.mesh, s), spec_tree,
            is_leaf=lambda x: isinstance(x, P))

    def _build_step(self, loss_fn):
        cfg = self.cfg
        opt = self.opt
        use_ef = cfg.grad_compression == "int8_ef"

        def one_step(params, opt_state, ef_state, step, batch):
            if cfg.microbatches > 1:
                def micro(carry, mb):
                    acc, = carry
                    (loss, _), g = jax.value_and_grad(
                        loss_fn, has_aux=True)(params, mb)
                    acc = jax.tree_util.tree_map(
                        lambda a, b: a + b.astype(jnp.float32), acc, g)
                    return (acc,), loss
                zeros = jax.tree_util.tree_map(
                    lambda p: jnp.zeros(p.shape, jnp.float32), params)
                (gsum,), losses = jax.lax.scan(micro, (zeros,), batch)
                grads = jax.tree_util.tree_map(
                    lambda g: g / cfg.microbatches, gsum)
                loss = jnp.mean(losses)
            else:
                (loss, _), grads = jax.value_and_grad(
                    loss_fn, has_aux=True)(params, batch)
            if use_ef:
                grads, ef_state = ef_compress_grads(grads, ef_state)
            new_params, new_opt = opt.update(grads, opt_state, params, step)
            return new_params, new_opt, ef_state, loss

        self._jit_step = jax.jit(one_step, donate_argnums=(0, 1, 2))

    # -- checkpoint state bundle -------------------------------------
    def _bundle(self):
        return {"params": self.params, "opt": self.opt_state,
                "ef": self.ef_state, "step": jnp.asarray(self.step)}

    def save(self):
        self.ckpt.save(self.step, self._bundle())

    def try_resume(self) -> bool:
        last = self.ckpt.latest_step()
        if last is None:
            return False
        restored, step = self.ckpt.restore(self._bundle())
        self.params = restored["params"]
        self.opt_state = restored["opt"]
        self.ef_state = restored["ef"]
        self.step = int(restored["step"])
        return True

    def median_step_sec(self, *, skip_first: int = 1) -> float:
        """Median wall time per completed step, excluding the first
        ``skip_first`` steps (compilation).  nan if nothing completed."""
        ts = self.step_seconds[skip_first:]
        if not ts:
            return float("nan")
        return statistics.median(ts)

    # -- main loop ----------------------------------------------------
    def _device_batch(self, step: int):
        batch = self.batch_fn(step)
        if self.cfg.microbatches > 1:
            batch = jax.tree_util.tree_map(
                lambda x: np.reshape(
                    x, (self.cfg.microbatches,
                        x.shape[0] // self.cfg.microbatches) + x.shape[1:]),
                batch)
        return self._shard_batch(batch)

    def _shard_batch(self, batch):
        """Lay the host batch out data-parallel before the step: the
        sample axis is placed on the mesh's 'batch' logical axes (PR 4
        — the shard_map DCL kernel path then consumes its local shard
        with no resharding; non-dividing batches fall back to
        replication via the logical-rules divisibility check).  The
        sample axis is axis 1 under gradient accumulation (axis 0 is
        the microbatch scan)."""
        if self.mesh is None:
            return batch
        axis = 1 if self.cfg.microbatches > 1 else 0

        def put(x):
            x = jnp.asarray(x)
            if x.ndim <= axis:
                return x
            axes = [None] * x.ndim
            axes[axis] = "batch"
            return jax.device_put(
                x, named_sharding(self.mesh, x.shape, axes))
        return jax.tree_util.tree_map(put, batch)

    def run(self) -> list[dict]:
        cfg = self.cfg
        retries = 0
        with use_rules(mesh=self.mesh):
            while self.step < cfg.total_steps:
                try:
                    if self.fault_hook is not None:
                        self.fault_hook(self.step)
                    batch = self._device_batch(self.step)
                    t0 = time.time()
                    (self.params, self.opt_state, self.ef_state,
                     loss) = self._jit_step(
                        self.params, self.opt_state, self.ef_state,
                        jnp.asarray(self.step), batch)
                    loss = float(loss)
                    dt = time.time() - t0
                    self.step_seconds.append(dt)
                    if self.step % cfg.log_every == 0:
                        self.history.append(
                            {"step": self.step, "loss": loss,
                             "sec": round(dt, 4)})
                    self.step += 1
                    retries = 0
                    if self.step % cfg.ckpt_every == 0:
                        self.save()
                except KeyboardInterrupt:
                    raise
                except Exception as e:  # noqa: BLE001 — node-failure path
                    retries += 1
                    if retries > cfg.max_retries:
                        raise
                    # Restore-and-replay: stateless data pipeline makes
                    # the retried step bit-exact.
                    if not self.try_resume():
                        # no checkpoint yet: restart from step 0 state is
                        # impossible — reraise
                        raise
                    self.history.append(
                        {"step": self.step, "event": f"recovered: {e}"})
            self.save()
            self.ckpt.wait()
        return self.history
