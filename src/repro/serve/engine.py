"""Slot-based continuous-batching serving engine.

A fixed decode batch of ``slots`` runs every step; requests stream in
and out of slots without stopping the batch (continuous batching à la
Orca/vLLM, on a static-shape TPU-friendly layout):

* admit: a free slot gets the new request — its prompt is prefilled
  with batch=1 and the resulting caches are written into the slot's
  batch row (static shapes; one ``dynamic_update_slice`` per cache leaf);
* step: ONE jitted decode step advances all active slots (inactive
  slots decode garbage that is masked out — the static-batch trade);
* retire: slots finishing (EOS or max_tokens) free immediately.

The decode step is the same ``decode_step`` the dry-run lowers, so what
is served here is exactly what the multi-pod config compiles.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.transformer import (ModelConfig, decode_step, init_cache,
                                      prefill)

Array = jax.Array


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray              # (S,) int32
    max_new_tokens: int = 16
    eos_id: int | None = None
    # filled by the engine:
    output: list[int] = dataclasses.field(default_factory=list)
    done: bool = False


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    slots: int = 4
    cache_len: int = 256


class ServingEngine:
    def __init__(self, params, cfg: ModelConfig, serve_cfg: ServeConfig):
        self.params = params
        self.cfg = cfg
        self.scfg = serve_cfg
        b, L = serve_cfg.slots, serve_cfg.cache_len
        self.caches = init_cache(cfg, b, L)
        self.pos = np.zeros((b,), np.int32)
        self.last_tok = np.zeros((b,), np.int32)
        self.active: list[Request | None] = [None] * b
        self.queue: deque[Request] = deque()
        self.completed: list[Request] = []

        def _step(p, c, t, pos):
            logits, new_c = decode_step(p, cfg, t, c, pos)
            return jnp.argmax(logits, axis=-1).astype(jnp.int32), new_c

        self._decode = jax.jit(_step)
        self._prefill = jax.jit(
            lambda p, t: prefill(p, cfg, t, cache_len=L))

    # ------------------------------------------------------------------
    def submit(self, req: Request) -> None:
        self.queue.append(req)

    def _write_slot(self, slot: int, slot_caches: Any) -> None:
        """Insert a batch=1 cache tree into batch row ``slot``.  The
        batch axis is located structurally: it is the first axis whose
        extent differs between the slot tree (1) and the engine tree
        (slots) — robust across prefix leaves (batch leading) and
        stacked period leaves (period axis leading)."""
        flat_full, treedef = jax.tree_util.tree_flatten(self.caches)
        flat_one = jax.tree_util.tree_flatten(slot_caches)[0]
        out = []
        for f, o in zip(flat_full, flat_one):
            # align ranks: both trees have identical structure; batch is
            # the first axis whose size differs (slots vs 1).
            start = [0] * f.ndim
            for ax in range(f.ndim):
                if f.shape[ax] != o.shape[ax]:
                    start[ax] = slot
                    break
            out.append(jax.lax.dynamic_update_slice(
                f, o.astype(f.dtype), tuple(start)))
        self.caches = jax.tree_util.tree_unflatten(treedef, out)

    def _admit(self) -> None:
        for slot in range(self.scfg.slots):
            if self.active[slot] is not None or not self.queue:
                continue
            req = self.queue.popleft()
            prompt = jnp.asarray(req.prompt, jnp.int32)[None]
            logits, caches1 = self._prefill(self.params, prompt)
            tok = int(jnp.argmax(logits[0], axis=-1))
            req.output.append(tok)
            self._write_slot(slot, caches1)
            self.pos[slot] = len(req.prompt)
            self.last_tok[slot] = tok
            self.active[slot] = req

    def _retire(self, slot: int) -> None:
        req = self.active[slot]
        req.done = True
        self.completed.append(req)
        self.active[slot] = None

    def step(self) -> int:
        """Admit + one decode step for all active slots.  Returns the
        number of active requests after the step."""
        self._admit()
        if not any(r is not None for r in self.active):
            return 0
        toks, self.caches = self._decode(
            self.params, self.caches,
            jnp.asarray(self.last_tok), jnp.asarray(self.pos))
        toks = np.asarray(toks)
        for slot, req in enumerate(self.active):
            if req is None:
                continue
            tok = int(toks[slot])
            req.output.append(tok)
            self.pos[slot] += 1
            self.last_tok[slot] = tok
            hit_eos = req.eos_id is not None and tok == req.eos_id
            full = self.pos[slot] + 1 >= self.scfg.cache_len
            if len(req.output) >= req.max_new_tokens or hit_eos or full:
                self._retire(slot)
        return sum(r is not None for r in self.active)

    def run_until_drained(self, max_steps: int = 10_000) -> list[Request]:
        steps = 0
        while (self.queue or any(r is not None for r in self.active)) \
                and steps < max_steps:
            self.step()
            steps += 1
        return self.completed
