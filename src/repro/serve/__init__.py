from .engine import ServeConfig, ServingEngine, Request  # noqa: F401
