from .engine import ServeConfig, ServingEngine, Request  # noqa: F401
from .admission import (AdmissionConfig, AdmissionQueue,  # noqa: F401
                        DeadlineExceeded, DetRequest, MalformedRequest,
                        OUTCOMES, resolve_bucket)
from .dcl_engine import (LADDER, DCLServeConfig,  # noqa: F401
                         DCLServingEngine, bucket_layer_dims)
