"""Slot-based detection serving engine for the bounded DCL models.

The LM engine (``serve.engine``) streams tokens through a static decode
batch; detection requests are single-shot, so the slot discipline here
is admit -> one batched forward -> retire, with the static-shape story
carried by *shape buckets*: a small fixed set of square resolutions,
each warmed at engine start with a memoized tile plan
(``kernels.plan.warm_tile_cache`` over the per-layer ``resolve_tiles``
lru-cache).  Every step serves one bucket — up to ``slots`` queued
requests padded into one static batch — so the jit caches stay closed
over ``len(buckets)`` shapes per datapath rung.

The default datapath is the paper's production configuration:
``quant="int8_chain"`` (fused in-kernel offset conv, int8 -> int8 layer
handoff) with calibration scale tables loaded at engine start.

Robustness layer (docs/serving.md):

* per-request deadlines — checked at admission, swept between steps,
  and re-checked after the serving step (a ``slow_step`` stall lands
  here); expiry is the typed ``deadline_exceeded`` outcome.
* bounded admission queue — ``serve.admission``; overload is shed
  (``shed_oldest``) or bounced (``reject_new``), never an exception.
* transient step failures — the failed batch (and ONLY that batch: the
  affected slots) is replayed with exponential backoff, up to
  ``max_retries`` per rung.
* per-request degradation ladder — persistent failures drop the batch
  one rung (int8_chain -> int8 -> fp32 kernel -> XLA reference) and
  replay.  The engine runs each batch under
  ``ops.degradation_scope(False)`` so kernel failures surface HERE and
  are recorded in each affected request's telemetry (``ladder``,
  ``degraded``) — not in ``ops``'s process-global warn-once fallback,
  so two engines in one process keep independent ladders and every
  degraded request reports its own rung.

The model forward runs eagerly (each ``ops.deform_conv*`` call is
itself jitted per static shape): the dispatch-hook seam and the
per-request ladder need per-step visibility, which an outer jit would
collapse to trace time.
"""
from __future__ import annotations

import contextlib
import dataclasses
import itertools
import time
from typing import Any, Callable, Mapping

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh

from repro.distributed.sharding import use_rules
from repro.kernels import ops, plan
from repro.models import resnet_dcn as R
from repro.obs import (DispatchRecorder, DivergenceTracker, MetricsRegistry,
                       Tracer)
from repro.obs import trace as _trace

from .admission import (AdmissionConfig, AdmissionQueue, DetRequest,
                        MalformedRequest, resolve_bucket)

__all__ = ["LADDER", "DCLServeConfig", "DCLServingEngine",
           "bucket_layer_dims"]

# Degradation ladder, top (production) rung first.  Mirrors the ops.py
# fallback ladder; the bottom rung never touches the kernel path.
LADDER = ("int8_chain", "int8", "fp32_kernel", "fp32_ref")


@dataclasses.dataclass(frozen=True)
class DCLServeConfig:
    buckets: tuple[int, ...] = (64, 128)
    slots: int = 4                   # static batch rows per step
    quant: str = "int8_chain"        # entry rung of LADDER
    strict_buckets: bool = True      # False: pad up to the next bucket
    queue_capacity: int = 64
    shed_policy: str = "reject_new"  # reject_new | shed_oldest
    max_retries: int = 2             # same-rung replays before degrading
    retry_backoff: float = 0.0       # seconds; doubles per consecutive retry
    default_deadline: float | None = None   # seconds from submit; None = off
    # Deadline-aware scheduling (ISSUE 10): a partial batch is held up
    # to batch_window seconds for more same-bucket arrivals; 0.0 serves
    # partials immediately (the pre-ISSUE-10 behavior).
    batch_window: float = 0.0
    # Spatial sharding (ISSUE 10): ((bucket, shards), ...) — the listed
    # buckets run their kernel rungs height-sharded over `shards`
    # devices with the bounded halo exchange (distributed.spatial).
    # Spatial buckets ladder from "int8": the chained datapath's fused
    # offset stage cannot be halo-split.
    spatial_shards: tuple[tuple[int, int], ...] = ()

    def __post_init__(self):
        if self.quant not in LADDER:
            raise ValueError(
                f"unknown serve datapath {self.quant!r}; expected one "
                f"of {LADDER} (the degradation ladder runs from the "
                f"chosen rung down)")
        if not self.buckets:
            raise ValueError("at least one shape bucket is required — "
                             "static compilation needs a closed shape set")
        if self.slots < 1:
            raise ValueError(f"slots must be >= 1 (got {self.slots})")
        if self.batch_window < 0:
            raise ValueError(
                f"batch_window must be >= 0 (got {self.batch_window})")
        for entry in self.spatial_shards:
            if len(entry) != 2:
                raise ValueError(
                    f"spatial_shards entries are (bucket, shards) pairs "
                    f"(got {entry!r})")
            b, s = entry
            if b not in self.buckets:
                raise ValueError(
                    f"spatial_shards names bucket {b} which is not in "
                    f"buckets {self.buckets}")
            if s < 1:
                raise ValueError(
                    f"spatial_shards for bucket {b} must be >= 1 "
                    f"(got {s})")

    def spatial_shards_for(self, bucket: int | None) -> int:
        for b, s in self.spatial_shards:
            if b == bucket:
                return s
        return 1


def bucket_layer_dims(cfg: R.ResNetDCNConfig, res: int) -> dict[str, dict]:
    """Dims of every DCL invocation at input resolution ``res`` — the
    shapes the bucket's tile plans are resolved against."""
    dims: dict[str, dict] = {}
    e = res // 4                       # stride-2 stem + stride-2 maxpool
    bi = 0
    for s, (n_blocks, width) in enumerate(zip(cfg.stage_sizes, cfg.widths)):
        for b in range(n_blocks):
            stride = 2 if (b == 0 and s > 0) else 1
            if cfg.is_dcn(bi):
                mid = width // 4
                dims[f"s{s}b{b}"] = dict(h=e, w=e, c=mid, m=mid,
                                         stride=stride)
            e //= stride
            bi += 1
    return dims


class DCLServingEngine:
    """See module docstring.  ``clock``/``sleep`` are injectable for
    deterministic deadline and backoff tests; ``step_hook(step, ctx)``
    and ``admit_hook(request)`` are the chaos seams
    (``resilience.ChaosHooks.serve_step_hook`` / ``admit_hook``)."""

    def __init__(self, params, model_cfg: R.ResNetDCNConfig,
                 serve_cfg: DCLServeConfig, *,
                 scale_table: Mapping[str, Any] | str | None = None,
                 clock: Callable[[], float] = time.monotonic,
                 sleep: Callable[[float], None] = time.sleep,
                 step_hook: Callable[[int, dict], None] | None = None,
                 admit_hook: Callable[[DetRequest], DetRequest] | None = None,
                 registry: MetricsRegistry | None = None,
                 tracer: Tracer | None = None):
        self.params = params
        self.scfg = serve_cfg
        self.clock = clock
        self._sleep = sleep
        self.step_hook = step_hook
        self.admit_hook = admit_hook

        # Observability (ISSUE 8).  Each engine defaults to its OWN
        # registry — two engines in one process never share counters,
        # matching the per-engine degradation-ladder isolation.  The
        # tracer defaults to the process-global one resolved at use
        # time (disabled unless a test/launcher opts in).
        self.metrics = registry if registry is not None else MetricsRegistry()
        self._tracer = tracer
        self.divergence = DivergenceTracker()
        m = self.metrics
        self._c_requests = m.counter(
            "serve_requests_total", "retired requests by outcome and bucket")
        self._c_retries = m.counter(
            "serve_retries_total", "same-rung batch replays")
        self._c_degraded = m.counter(
            "serve_degraded_batches_total", "batches dropped one ladder rung")
        self._c_ladder = m.counter(
            "serve_ladder_total", "requests served per datapath rung")
        self._c_steps = m.counter(
            "serve_steps_total", "engine serving steps")
        self._g_queue = m.gauge(
            "serve_queue_depth", "queued requests after the last step")
        self._h_queue_wait = m.histogram(
            "serve_queue_wait_seconds",
            "submit-to-batch-start wait per bucket")
        self._h_latency = m.histogram(
            "serve_latency_seconds",
            "submit-to-retire latency per bucket and outcome")

        if isinstance(scale_table, str):
            from repro.quant.calibrate import load_scale_table
            scale_table = load_scale_table(scale_table)
        self.scale_table = scale_table
        if serve_cfg.quant in ("int8_chain", "int8"):
            if model_cfg.offset_bound is None:
                raise ValueError(
                    f"serve datapath {serve_cfg.quant!r} needs a trained "
                    f"offset_bound on the model config — the bounded "
                    f"band DMA is the whole int8 story (Eq. 6)")
            if scale_table is None:
                raise ValueError(
                    f"serve datapath {serve_cfg.quant!r} needs a "
                    f"calibration scale table at engine start "
                    f"(repro.quant.calibrate_resnet_dcn + "
                    f"save_scale_table); chained layers exchange int8 "
                    f"on pinned activation grids")

        # One model config per ladder rung; the rung is chosen per batch
        # attempt, so all four stay ready.
        self._cfgs = {
            "int8_chain": dataclasses.replace(
                model_cfg, quant="int8_chain", use_kernel=True),
            "int8": dataclasses.replace(
                model_cfg, quant="int8", use_kernel=True),
            "fp32_kernel": dataclasses.replace(
                model_cfg, quant="none", use_kernel=True),
            "fp32_ref": dataclasses.replace(
                model_cfg, quant="none", use_kernel=False),
        }

        # Spatial sharding (ISSUE 10): per-bucket meshes for the
        # height-sharded kernel rungs.  Shard counts are validated
        # against the real device count HERE — a misconfigured engine
        # fails at construction, not on the first sharded request.
        self._spatial_meshes: dict[int, Mesh] = {}
        for b, s in serve_cfg.spatial_shards:
            if s > jax.device_count():
                raise ValueError(
                    f"spatial_shards={s} for bucket {b} exceeds the "
                    f"{jax.device_count()} available device(s) — the "
                    f"height split needs one device per shard")
            if s > 1:
                if model_cfg.offset_bound is None:
                    raise ValueError(
                        f"spatial_shards={s} for bucket {b} needs a "
                        f"trained offset_bound on the model config — the "
                        f"bounded halo exchange is derived from it")
                self._spatial_meshes[b] = Mesh(
                    np.asarray(jax.devices()[:s]), ("model",))

        # Per-bucket plan cache: resolve every DCL tile config now, so
        # the chooser sweep happens at engine start, not first request.
        int8ish = serve_cfg.quant in ("int8_chain", "int8")
        plan_dtype = "int8" if int8ish else None
        self.plans: dict[int, dict[str, tuple]] = {}
        # Per-layer plan provenance (ISSUE 9): "tuned" when the layer's
        # tiles came from the installed autotuner cache (repro.tune),
        # "analytic" for the Sec. 3.2 chooser — surfaced in telemetry()
        # and serve_bench so a cold/ignored cache is visible.  Spatial
        # buckets warm the per-shard (local-height) plans the sharded
        # path actually resolves and tag the provenance with the shard
        # count ("analytic@2shard") — ISSUE 10 satellite: warming the
        # global-height plans would leave every sharded dispatch cold.
        self.plan_sources: dict[int, dict[str, str]] = {}
        if model_cfg.offset_bound is not None:
            for b in serve_cfg.buckets:
                dims = bucket_layer_dims(model_cfg, b)
                shards = serve_cfg.spatial_shards_for(b)
                self.plans[b] = plan.warm_tile_cache(
                    dims,
                    offset_bound=model_cfg.offset_bound,
                    objective="forward",
                    dtype=plan_dtype,
                    spatial_shards=shards)
                suffix = f"@{shards}shard" if shards > 1 else ""
                self.plan_sources[b] = {
                    name: plan.tile_source(
                        d["h"], d["w"], d["c"], d["m"],
                        stride=d.get("stride", 1),
                        offset_bound=model_cfg.offset_bound,
                        objective="forward", dtype=plan_dtype,
                        spatial_shards=shards) + suffix
                    for name, d in dims.items()}

        self.queue = AdmissionQueue(AdmissionConfig(
            capacity=serve_cfg.queue_capacity,
            policy=serve_cfg.shed_policy))
        self.completed: list[DetRequest] = []
        self.steps = 0
        self._uid = itertools.count()

    @property
    def _tr(self) -> Tracer:
        return self._tracer if self._tracer is not None \
            else _trace.get_tracer()

    @property
    def counters(self) -> dict[str, int]:
        """Legacy counters view, now rendered FROM the metrics registry
        (ISSUE 8): ``{outcome: count}`` summed over buckets, plus
        ``retries`` / ``degraded_batches`` when nonzero — the exact
        shape the pre-obs ad-hoc dict had, so dict-equality callers
        keep working."""
        out: dict[str, int] = {}
        for key, v in self._c_requests.items():
            outcome = dict(key)["outcome"]
            out[outcome] = out.get(outcome, 0) + int(v)
        retries = int(self._c_retries.value())
        if retries:
            out["retries"] = retries
        degraded = int(self._c_degraded.value())
        if degraded:
            out["degraded_batches"] = degraded
        return out

    # -- admission -----------------------------------------------------
    def submit(self, image, *, deadline: float | None = None,
               uid: int | None = None) -> DetRequest:
        """Admit a detection request.  ``deadline`` is seconds from now
        on the engine clock.  The returned request is either queued or
        already retired with a typed outcome (rejected / shed /
        malformed / unbucketable / deadline_exceeded) — admission never
        raises on bad traffic."""
        now = self.clock()
        if deadline is None and self.scfg.default_deadline is not None:
            deadline = self.scfg.default_deadline
        req = DetRequest(
            uid=next(self._uid) if uid is None else uid, image=image,
            deadline=None if deadline is None else now + deadline,
            submitted_at=now)
        self._tr.event("serve/admit", uid=req.uid)
        if self.admit_hook is not None:
            req = self.admit_hook(req) or req

        try:
            arr = np.asarray(req.image)
            if arr.ndim != 3 or arr.shape[-1] != 3 \
                    or not np.issubdtype(arr.dtype, np.number):
                raise MalformedRequest(
                    f"detection request needs a numeric (H, W, 3) "
                    f"image; got shape {arr.shape} dtype {arr.dtype}")
        except Exception as e:
            return self._retire(req, "malformed",
                                f"{type(e).__name__}: {e}")
        try:
            req.bucket = resolve_bucket(arr.shape[0], arr.shape[1],
                                        self.scfg.buckets,
                                        strict=self.scfg.strict_buckets)
        except ValueError as e:
            return self._retire(req, "unbucketable", str(e))
        if req.deadline is not None and now > req.deadline:
            return self._retire(req, "deadline_exceeded",
                                "expired at admission")
        displaced = self.queue.offer(req)
        if displaced is not None:
            self._retire(displaced)
        return req

    def _retire(self, req: DetRequest, outcome: str | None = None,
                error: str = "") -> DetRequest:
        if outcome is not None:
            req.outcome = outcome
            if error:
                req.error = error
        req.done = True
        req.completed_at = self.clock()
        self.completed.append(req)
        bucket = str(req.bucket)
        self._c_requests.inc(outcome=req.outcome, bucket=bucket)
        lat = req.latency_s()
        if lat is not None:
            self._h_latency.observe(lat, bucket=bucket, outcome=req.outcome)
        self._tr.event("serve/retire", uid=req.uid, outcome=req.outcome)
        return req

    # -- serving -------------------------------------------------------
    def step(self) -> int:
        """Expire, pick the most urgent bucket (oldest-deadline-first,
        full batches preferred — ``AdmissionQueue.pick_bucket``), serve
        it.  Returns the number of requests retired this step."""
        before = len(self.completed)
        for req in self.queue.expire(self.clock()):
            self._retire(req)
        bucket = self.queue.pick_bucket(
            slots=self.scfg.slots, now=self.clock(),
            batch_window=self.scfg.batch_window)
        if bucket is None:
            self._g_queue.set(len(self.queue))
            return len(self.completed) - before
        batch = self.queue.take(bucket, self.scfg.slots)
        with self._tr.span("serve/step", step=self.steps, bucket=bucket,
                           size=len(batch)):
            now = self.clock()
            for r in batch:
                self._h_queue_wait.observe(now - r.submitted_at,
                                           bucket=str(bucket))
            if self.step_hook is not None:
                self.step_hook(self.steps,
                               {"bucket": bucket, "size": len(batch)})
            self._run_batch(bucket, batch)
        self.steps += 1
        self._c_steps.inc()
        self._g_queue.set(len(self.queue))
        return len(self.completed) - before

    def _batch_array(self, bucket: int, reqs: list[DetRequest]) -> Any:
        images = np.zeros((self.scfg.slots, bucket, bucket, 3), np.float32)
        for i, r in enumerate(reqs):
            arr = np.asarray(r.image, np.float32)
            images[i, :arr.shape[0], :arr.shape[1], :] = arr
        return jnp.asarray(images)

    def _forward(self, rung: str, x, bucket: int | None = None):
        cfg = self._cfgs[rung]
        # Spatial buckets (ISSUE 10): the kernel rungs run height-
        # sharded under the bucket's mesh; the chained rung never gets
        # here for them (_run_batch enters the ladder at "int8") and
        # the reference rung has no shard_map wrap.
        shards = self.scfg.spatial_shards_for(bucket)
        spatial = shards > 1 and rung in ("int8", "fp32_kernel")
        if spatial:
            cfg = dataclasses.replace(cfg, shard_spatial=True)
        mesh_ctx = use_rules(mesh=self._spatial_meshes[bucket]) \
            if spatial else contextlib.nullcontext()
        # Instrument every bounded dispatch in this forward: the
        # recorder chains to whatever hook is already installed (the
        # chaos harness), so injected faults still fire FIRST and abort
        # before any timing starts.
        rec = DispatchRecorder(
            registry=self.metrics, tracer=self._tracer,
            tracker=self.divergence, next_hook=ops.get_dispatch_hook(),
            clock=self.clock)
        with mesh_ctx, ops.dispatch_hook_scope(rec), \
                ops.degradation_scope(False):
            out, _ = R.forward(self.params, cfg, x,
                               quant_scales=self.scale_table)
        return out

    def _run_batch(self, bucket: int, reqs: list[DetRequest]) -> None:
        x = self._batch_array(bucket, reqs)
        rung_idx = LADDER.index(self.scfg.quant)
        if self.scfg.spatial_shards_for(bucket) > 1 \
                and LADDER[rung_idx] == "int8_chain":
            # Chained int8 cannot halo-split its fused offset stage;
            # spatial buckets enter the ladder one rung down.
            rung_idx = LADDER.index("int8")
        attempt = 0
        while True:
            try:
                out = self._forward(LADDER[rung_idx], x, bucket)
                break
            except Exception as e:          # noqa: BLE001 — typed below
                self._c_retries.inc()
                self._tr.event("serve/retry", bucket=bucket,
                               rung=LADDER[rung_idx], attempt=attempt + 1)
                for r in reqs:
                    r.retries += 1
                attempt += 1
                if attempt <= self.scfg.max_retries:
                    # transient: replay the affected slots, same rung
                    if self.scfg.retry_backoff:
                        self._sleep(self.scfg.retry_backoff
                                    * 2 ** (attempt - 1))
                    continue
                if rung_idx + 1 < len(LADDER):
                    # persistent: drop one rung, fresh retry budget
                    rung_idx += 1
                    attempt = 0
                    for r in reqs:
                        r.degraded = True
                    self._c_degraded.inc()
                    self._tr.event("serve/degrade", bucket=bucket,
                                   rung=LADDER[rung_idx])
                    continue
                for r in reqs:              # bottom rung failed: typed
                    self._retire(r, "failed",
                                 f"{type(e).__name__}: {e}")
                return
        now = self.clock()
        cls = np.asarray(out["cls"])
        box = np.asarray(out["box"])
        for i, r in enumerate(reqs):
            r.ladder = LADDER[rung_idx]
            self._c_ladder.inc(rung=r.ladder)
            if r.deadline is not None and now > r.deadline:
                self._retire(r, "deadline_exceeded",
                             f"completed {now - r.deadline:.3f}s past "
                             f"deadline (result dropped)")
                continue
            r.result = {"cls": cls[i], "box": box[i]}
            self._retire(r, "ok")

    def run_until_drained(self, max_steps: int = 10_000
                          ) -> list[DetRequest]:
        steps = 0
        while len(self.queue) and steps < max_steps:
            self.step()
            steps += 1
        return self.completed

    # -- telemetry -----------------------------------------------------
    def telemetry(self) -> dict:
        """Per-request records + engine counters — the schema
        ``resilience.dump_telemetry`` writes (docs/serving.md)."""
        per_bucket: dict[str, int] = {}
        for r in self.completed:
            if r.outcome == "ok":
                key = str(r.bucket)
                per_bucket[key] = per_bucket.get(key, 0) + 1
        return {
            "engine": {
                "buckets": list(self.scfg.buckets),
                "slots": self.scfg.slots,
                "quant": self.scfg.quant,
                "strict_buckets": self.scfg.strict_buckets,
                "queue_capacity": self.scfg.queue_capacity,
                "shed_policy": self.scfg.shed_policy,
                "batch_window": self.scfg.batch_window,
                "spatial_shards": [list(e)
                                   for e in self.scfg.spatial_shards],
            },
            "steps": self.steps,
            "counters": dict(self.counters),
            "served_per_bucket": per_bucket,
            "plan_cache": plan.tile_cache_info(),
            "plans": {str(b): {k: list(v) for k, v in p.items()}
                      for b, p in self.plans.items()},
            "plan_sources": {str(b): dict(s)
                             for b, s in self.plan_sources.items()},
            "requests": [{
                "uid": r.uid, "outcome": r.outcome, "bucket": r.bucket,
                "ladder": r.ladder, "degraded": r.degraded,
                "retries": r.retries, "latency_s": r.latency_s(),
                "error": r.error,
            } for r in self.completed],
            "metrics": self.metrics.snapshot(),
            "divergence": self.divergence.report(),
        }
