"""Admission control for the DCL detection serving engine.

Everything that can refuse a request lives here, typed:

* :func:`resolve_bucket` — map a request resolution onto the engine's
  fixed shape buckets (static compilation demands a closed shape set);
  a miss raises a friendly ``ValueError`` naming the resolution and the
  nearest configured buckets, or pads up with ``strict=False``.
* :class:`AdmissionQueue` — a bounded FIFO with a configurable
  load-shedding policy: ``reject_new`` (backpressure — the submitter's
  request bounces) or ``shed_oldest`` (the head of the queue is
  sacrificed for the newcomer).
* deadline bookkeeping — requests carry an absolute engine-clock
  deadline; :meth:`AdmissionQueue.expire` sweeps the queue between
  steps and a :class:`DeadlineExceeded` is recorded (never raised
  across the engine boundary) as the typed ``deadline_exceeded``
  outcome.

A refused request is never an exception at the ``submit()`` call site:
it comes back retired with one of the :data:`OUTCOMES` and a
human-readable ``error`` — overload and malformed traffic are expected
inputs for a serving system, not crashes.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Any

__all__ = [
    "OUTCOMES", "DeadlineExceeded", "MalformedRequest", "DetRequest",
    "AdmissionConfig", "AdmissionQueue", "resolve_bucket",
]

# Every terminal state a request can reach.  "ok" is the only one with
# a result; the rest carry the reason in ``error``.
OUTCOMES = ("ok", "rejected", "shed", "deadline_exceeded", "malformed",
            "unbucketable", "failed")

SHED_POLICIES = ("reject_new", "shed_oldest")


class DeadlineExceeded(RuntimeError):
    """Typed expiry: the request's deadline passed before (or while)
    it was served.  Checked at admission and between engine steps."""


class MalformedRequest(ValueError):
    """The request payload is not a detection image."""


@dataclasses.dataclass
class DetRequest:
    """One detection request and its full lifecycle record."""
    uid: int
    image: Any                       # (H, W, 3) array-like
    deadline: float | None = None    # absolute, on the engine clock
    # filled by the engine:
    bucket: int | None = None
    outcome: str = "pending"
    error: str = ""
    ladder: str | None = None        # datapath rung that actually served it
    degraded: bool = False
    retries: int = 0
    submitted_at: float | None = None
    completed_at: float | None = None
    result: dict | None = None       # {"cls", "box"} for outcome == "ok"
    done: bool = False

    def latency_s(self) -> float | None:
        if self.submitted_at is None or self.completed_at is None:
            return None
        return self.completed_at - self.submitted_at


def resolve_bucket(h: int, w: int, buckets, *, strict: bool = True) -> int:
    """Map an ``h x w`` request onto one of the configured square shape
    ``buckets`` (each bucket is one static compilation of the model).

    ``strict=True`` requires an exact square match (``h == w == b``);
    ``strict=False`` pads up to the smallest bucket covering both
    extents (the engine zero-pads the image, which the bounded kernels'
    own zero-padding semantics absorb).  A resolution no bucket can
    serve raises a ``ValueError`` naming the request and the nearest
    buckets — mirroring ``models.layers.check_chain_compat``.
    """
    buckets = tuple(sorted(buckets))
    if not buckets:
        raise ValueError("no shape buckets configured")
    side = max(int(h), int(w))
    if strict:
        if h == w and h in buckets:
            return int(h)
        below = max((b for b in buckets if b <= side), default=None)
        above = min((b for b in buckets if b >= side), default=None)
        near = " and ".join(f"{b}x{b}" for b in (below, above)
                            if b is not None)
        raise ValueError(
            f"request resolution {h}x{w} matches no configured shape "
            f"bucket {buckets} — nearest: {near}; resize the request, "
            f"add a bucket, or serve with strict_buckets=False to pad "
            f"up to the next bucket")
    above = min((b for b in buckets if b >= side), default=None)
    if above is None:
        raise ValueError(
            f"request resolution {h}x{w} exceeds the largest configured "
            f"shape bucket {buckets[-1]}x{buckets[-1]} (buckets "
            f"{buckets}); padding only goes UP — add a larger bucket or "
            f"downscale the request")
    return int(above)


@dataclasses.dataclass(frozen=True)
class AdmissionConfig:
    capacity: int = 64
    policy: str = "reject_new"       # reject_new | shed_oldest

    def __post_init__(self):
        if self.capacity < 1:
            raise ValueError(
                f"admission capacity must be >= 1 (got {self.capacity})")
        if self.policy not in SHED_POLICIES:
            raise ValueError(
                f"unknown shed policy {self.policy!r}; expected one of "
                f"{SHED_POLICIES}")


class AdmissionQueue:
    """Bounded FIFO of admitted-but-unserved requests."""

    def __init__(self, cfg: AdmissionConfig):
        self.cfg = cfg
        self.queue: deque[DetRequest] = deque()

    def __len__(self) -> int:
        return len(self.queue)

    def offer(self, req: DetRequest) -> DetRequest | None:
        """Enqueue ``req``.  Returns the displaced request — marked
        ``rejected`` (the newcomer, under backpressure) or ``shed``
        (the oldest queued request, under shed-oldest) — or None when
        there was room."""
        if len(self.queue) < self.cfg.capacity:
            self.queue.append(req)
            return None
        if self.cfg.policy == "shed_oldest":
            victim = self.queue.popleft()
            victim.outcome = "shed"
            victim.error = (
                f"shed by request {req.uid}: queue at capacity "
                f"{self.cfg.capacity} (policy=shed_oldest)")
            self.queue.append(req)
            return victim
        req.outcome = "rejected"
        req.error = (f"queue at capacity {self.cfg.capacity} "
                     f"(policy=reject_new)")
        return req

    def expire(self, now: float) -> list[DetRequest]:
        """Sweep deadline-expired requests out of the queue, marking
        each with the typed ``deadline_exceeded`` outcome."""
        expired = []
        keep = deque()
        for req in self.queue:
            if req.deadline is not None and now > req.deadline:
                req.outcome = "deadline_exceeded"
                req.error = str(DeadlineExceeded(
                    f"request {req.uid} expired in queue "
                    f"({now - req.deadline:.3f}s past deadline)"))
                expired.append(req)
            else:
                keep.append(req)
        self.queue = keep
        return expired

    def head_bucket(self) -> int | None:
        """Bucket of the oldest queued request (the next step's batch)."""
        return self.queue[0].bucket if self.queue else None

    def pick_bucket(self, *, slots: int, now: float,
                    batch_window: float = 0.0) -> int | None:
        """Deadline-aware bucket pick (ISSUE 10): the bucket whose most
        urgent request has the earliest deadline, instead of blind
        head-of-line order — a full queue of lax-deadline 64px requests
        no longer starves a tight-deadline 128px request behind them.

        Buckets that can fill all ``slots`` are preferred (a full static
        batch wastes no padded rows); among them — and among the partial
        ones — order is (earliest deadline, oldest submit), with
        deadline-less requests sorting last (+inf).  A *partial* bucket
        is only eligible once its oldest request has waited at least
        ``batch_window`` seconds, so a small window trades a bounded
        extra wait for fuller batches (``batch_window=0`` serves
        partials immediately — the pre-ISSUE-10 behavior).  Returns
        None when the queue is empty or every partial batch is still
        inside its window.
        """
        stats: dict[int, tuple[int, float, float]] = {}
        for req in self.queue:
            dl = req.deadline if req.deadline is not None else float("inf")
            sub = req.submitted_at if req.submitted_at is not None \
                else float("inf")
            count, best_dl, oldest = stats.get(
                req.bucket, (0, float("inf"), float("inf")))
            stats[req.bucket] = (count + 1, min(best_dl, dl),
                                 min(oldest, sub))
        if not stats:
            return None
        order = sorted(stats, key=lambda b: (stats[b][1], stats[b][2]))
        for b in order:
            if stats[b][0] >= slots:
                return b
        for b in order:
            oldest = stats[b][2]
            if batch_window <= 0.0 or now - oldest >= batch_window:
                return b
        return None

    def take(self, bucket: int, limit: int) -> list[DetRequest]:
        """Pop up to ``limit`` requests for ``bucket``, preserving FIFO
        order; requests for other buckets stay queued in place."""
        taken: list[DetRequest] = []
        keep = deque()
        for req in self.queue:
            if req.bucket == bucket and len(taken) < limit:
                taken.append(req)
            else:
                keep.append(req)
        self.queue = keep
        return taken
