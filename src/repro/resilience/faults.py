"""Chaos harness: seeded, composable fault injectors.

The fault model covers the failure classes a long DCL training run
actually meets (ISSUE 6; CoDeNet's input-adaptive deployment setting is
exactly the regime where the job must keep running through them):

* ``nonfinite_grads`` — a batch is poisoned with NaN, so loss and
  gradients go non-finite; the Trainer's sentinels must skip-and-log
  the step instead of stepping the optimizer into NaN.
* ``step_crash``    — the step raises (:class:`DeviceLost`), modeling a
  device loss / preempted worker; the Trainer retries from the last
  checkpoint with restore-and-replay.
* ``ckpt_corrupt``  — the latest *complete* checkpoint on disk is
  corrupted (truncated leaf or bad manifest) and the device is lost in
  the same event (the classic "node died while its newest checkpoint
  was half-written"); restore must CRC-verify and fall back to the
  previous complete step.
* ``data_hiccup``   — the input pipeline raises a transient
  :class:`DataPipelineHiccup`; the retry path absorbs it.
* ``dispatch_fault`` — the kernel dispatcher hook raises
  :class:`KernelDispatchFault`; ``ops.deform_conv`` must degrade to the
  XLA reference path with one logged warning (see docs/robustness.md,
  "degradation ladder").

Serve-time fault kinds (PR 7) target the DCL serving engine
(``repro.serve.dcl_engine``) through its ``step_hook``/``admit_hook``
seams the same way the trainer kinds target the Trainer:

* ``slow_step``         — one engine step stalls (``mode`` = seconds,
  default 0.05); requests with tight deadlines must expire with a typed
  ``deadline_exceeded`` outcome instead of hanging a slot.
* ``malformed_request`` — a submitted request's image is replaced with
  a rank-1 plane; admission must refuse it with a typed ``malformed``
  outcome.
* ``bucket_miss_storm`` — a burst of requests (``mode`` = count,
  default 3) is diverted to a resolution matching no configured shape
  bucket; a strict engine must shed them all with typed
  ``unbucketable`` outcomes, not crash or wedge the queue.

Every injector is one-shot (a consumed event never re-fires), so
restore-and-replay after a crash cannot loop on its own fault, and a
chaos run is reproducible: :meth:`FaultPlan.random` derives the whole
schedule from one integer seed.
"""
from __future__ import annotations

import dataclasses
import pathlib
import time
from typing import Any, Sequence

import numpy as np

__all__ = [
    "FAULT_KINDS", "FaultInjected", "DeviceLost", "DataPipelineHiccup",
    "KernelDispatchFault", "FaultEvent", "FaultPlan", "ChaosHooks",
    "corrupt_checkpoint", "dump_telemetry",
]

FAULT_KINDS = ("nonfinite_grads", "step_crash", "ckpt_corrupt",
               "data_hiccup", "dispatch_fault",
               # serve-time kinds (DCL serving engine seams)
               "slow_step", "malformed_request", "bucket_miss_storm")


class FaultInjected(RuntimeError):
    """Marker base: this failure came from the chaos harness."""


class DeviceLost(FaultInjected):
    """Injected device loss — the step raises mid-flight."""


class DataPipelineHiccup(FaultInjected):
    """Injected transient input-pipeline failure."""


class KernelDispatchFault(FaultInjected):
    """Injected kernel-dispatch failure (the dispatcher-hook seam)."""


@dataclasses.dataclass(frozen=True)
class FaultEvent:
    """One scheduled fault: fire ``kind`` when the run reaches ``step``."""
    step: int
    kind: str
    mode: str = ""          # injector detail (e.g. corruption mode)

    def __post_init__(self):
        if self.kind not in FAULT_KINDS:
            raise ValueError(
                f"unknown fault kind {self.kind!r}; expected one of "
                f"{FAULT_KINDS}")


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """A reproducible fault schedule: (seed, events).

    Build explicitly from events, or with :meth:`random` which derives
    everything from the seed — the chaos CI job records the seed in its
    telemetry artifact so any run can be replayed bit-for-bit.
    """
    events: tuple[FaultEvent, ...] = ()
    seed: int | None = None

    @classmethod
    def random(cls, seed: int, *, total_steps: int,
               kinds: Sequence[str] = ("nonfinite_grads", "ckpt_corrupt",
                                       "step_crash", "data_hiccup"),
               min_step: int = 1) -> "FaultPlan":
        """Seeded random schedule with one event per requested kind.

        The kinds keep their listed ORDER over the step range (each is
        placed at a random step inside its own window), so a schedule
        like (corrupt, crash) always corrupts before it crashes — the
        combination that exercises the checkpoint CRC fallback — while
        the exact steps stay randomized per seed.
        """
        if total_steps - min_step < len(kinds):
            raise ValueError(
                f"total_steps={total_steps} leaves fewer than "
                f"{len(kinds)} steps after min_step={min_step} — one "
                f"window per fault kind is needed")
        rng = np.random.default_rng(seed)
        span = total_steps - min_step
        events = []
        for i, kind in enumerate(kinds):
            lo = min_step + (i * span) // len(kinds)
            hi = min_step + ((i + 1) * span) // len(kinds)
            step = int(rng.integers(lo, max(hi, lo + 1)))
            mode = ""
            if kind == "ckpt_corrupt":
                mode = str(rng.choice(["truncate_leaf", "bad_manifest"]))
            events.append(FaultEvent(step=step, kind=kind, mode=mode))
        return cls(events=tuple(events), seed=seed)

    def at(self, step: int) -> list[tuple[int, FaultEvent]]:
        """(index, event) pairs scheduled for ``step``."""
        return [(i, e) for i, e in enumerate(self.events) if e.step == step]

    def kinds(self) -> set[str]:
        return {e.kind for e in self.events}

    def summary(self) -> dict:
        return {"seed": self.seed,
                "events": [dataclasses.asdict(e) for e in self.events]}


# ---------------------------------------------------------------------------
# Telemetry — the JSON sink moved to ``repro.obs.metrics`` (ISSUE 8:
# one exporter for every subsystem); re-exported here because the
# chaos tests and older callers import it from ``repro.resilience``.
# ---------------------------------------------------------------------------

from repro.obs.metrics import _json_default, dump_telemetry  # noqa: E402,F401


# ---------------------------------------------------------------------------
# Checkpoint corruption
# ---------------------------------------------------------------------------

def corrupt_checkpoint(directory, *, step: int | None = None,
                       mode: str = "truncate_leaf") -> pathlib.Path:
    """Corrupt one complete checkpoint in ``directory`` (latest if
    ``step`` is None) the way a crash mid-write / bit-rot would:

    * ``truncate_leaf`` — chop the first leaf file in half (CRC and the
      npy header both break);
    * ``bad_manifest``  — overwrite ``manifest.json`` with junk.

    Returns the corrupted checkpoint path.  Restoring it must fail the
    CRC/manifest verification and fall back to the previous complete
    step (``repro.checkpoint.restore_checkpoint``).
    """
    from repro.checkpoint.checkpoint import complete_steps

    directory = pathlib.Path(directory)
    if step is None:
        steps = complete_steps(directory)
        if not steps:
            raise FileNotFoundError(f"no complete checkpoint in {directory}")
        step = steps[0]
    path = directory / f"step_{step:08d}"
    if mode == "bad_manifest":
        (path / "manifest.json").write_text("{not json")
    elif mode == "truncate_leaf":
        leaf = path / "000.npy"
        data = leaf.read_bytes()
        leaf.write_bytes(data[: max(1, len(data) // 2)])
    else:
        raise ValueError(
            f"unknown corruption mode {mode!r}; expected 'truncate_leaf' "
            f"or 'bad_manifest'")
    return path


# ---------------------------------------------------------------------------
# Hook bundle: FaultPlan -> Trainer / dispatcher seams
# ---------------------------------------------------------------------------

class ChaosHooks:
    """Bind a :class:`FaultPlan` to the runtime seams.

    * ``fault_hook(step)``   -> ``Trainer(fault_hook=...)`` — raises for
      ``step_crash``/``data_hiccup``; for ``ckpt_corrupt`` it corrupts
      the latest complete checkpoint on disk AND raises
      :class:`DeviceLost` (corruption alone is invisible until a
      restore needs the file).
    * ``batch_hook(step, batch)`` -> ``Trainer(batch_hook=...)`` —
      poisons the batch with NaN for ``nonfinite_grads``.
    * ``dispatch_hook(context)``  -> ``kernels.ops.set_dispatch_hook``
      — raises :class:`KernelDispatchFault` once per armed
      ``dispatch_fault`` event (the dispatcher has no step counter, so
      these are consumed per call).
    * ``serve_step_hook(step, ctx)`` -> ``DCLServingEngine(step_hook=...)``
      — stalls the engine step for ``slow_step`` events.
    * ``admit_hook(request)`` -> ``DCLServingEngine(admit_hook=...)`` —
      corrupts submitted requests (``malformed_request``,
      ``bucket_miss_storm``); admission has no step counter, so these
      are armed in plan order and consumed per submitted request.

    ``fired`` records every injection (kind, step, detail) — the chaos
    telemetry the CI job uploads.  ``bind(trainer)`` lets the
    checkpoint injector drain the trainer's async writer before
    corrupting, so "latest complete step" is deterministic.  ``sleep``
    is the stall primitive of ``slow_step`` — tests running the engine
    on a fake clock point it at the clock's ``advance`` so the stall
    is deterministic regardless of wall time.
    """

    def __init__(self, plan: FaultPlan, *, ckpt_dir=None, sleep=time.sleep):
        self.plan = plan
        self.ckpt_dir = ckpt_dir
        self.trainer = None
        self.sleep = sleep
        self.fired: list[dict] = []
        self._consumed: set[int] = set()
        self._armed_dispatch = [
            i for i, e in enumerate(plan.events)
            if e.kind == "dispatch_fault"]
        self._armed_admission = [
            i for i, e in enumerate(plan.events)
            if e.kind in ("malformed_request", "bucket_miss_storm")]
        self._storm_left = 0

    def bind(self, trainer) -> "ChaosHooks":
        self.trainer = trainer
        if self.ckpt_dir is None:
            self.ckpt_dir = trainer.cfg.ckpt_dir
        return self

    def _fire(self, i: int, event: FaultEvent, **detail) -> None:
        self._consumed.add(i)
        self.fired.append({"step": event.step, "kind": event.kind,
                           "mode": event.mode, **detail})
        # ISSUE 8: every injection is also an instant event on the
        # process-global tracer (resolved at fire time so tests'
        # tracer_scope sees it) — chaos runs leave their faults in the
        # same trace the spans land in.
        from repro.obs.trace import get_tracer
        get_tracer().event(f"fault/{event.kind}", step=event.step,
                           mode=event.mode)

    # -- Trainer seams -------------------------------------------------
    def fault_hook(self, step: int) -> None:
        for i, ev in self.plan.at(step):
            if i in self._consumed:
                continue
            if ev.kind == "step_crash":
                self._fire(i, ev)
                raise DeviceLost(f"injected device loss at step {step}")
            if ev.kind == "data_hiccup":
                self._fire(i, ev)
                raise DataPipelineHiccup(
                    f"injected data-pipeline hiccup at step {step}")
            if ev.kind == "ckpt_corrupt":
                if self.trainer is not None:
                    self.trainer.ckpt.wait()
                try:
                    path = corrupt_checkpoint(
                        self.ckpt_dir, mode=ev.mode or "truncate_leaf")
                except FileNotFoundError:
                    # Nothing on disk yet: corruption is a no-op, but
                    # the device loss still fires.
                    path = None
                self._fire(i, ev, path=str(path))
                raise DeviceLost(
                    f"injected device loss at step {step} (latest "
                    f"checkpoint corrupted: {path})")

    def batch_hook(self, step: int, batch: Any) -> Any:
        import jax
        import jax.numpy as jnp

        for i, ev in self.plan.at(step):
            if i in self._consumed or ev.kind != "nonfinite_grads":
                continue
            self._fire(i, ev)

            def poison(x):
                x = jnp.asarray(x)
                if jnp.issubdtype(x.dtype, jnp.floating):
                    return jnp.full_like(x, jnp.nan)
                return x
            batch = jax.tree_util.tree_map(poison, batch)
        return batch

    # -- dispatcher seam ----------------------------------------------
    def dispatch_hook(self, context: dict) -> None:
        if self._armed_dispatch:
            i = self._armed_dispatch.pop(0)
            self._fire(i, self.plan.events[i], context=dict(context))
            raise KernelDispatchFault(
                f"injected kernel-dispatch failure ({context.get('op')})")

    # -- serving seams -------------------------------------------------
    def serve_step_hook(self, step: int, context: dict | None = None
                        ) -> None:
        """``DCLServingEngine(step_hook=...)``: stall ``slow_step``
        events scheduled for this engine step (``mode`` = seconds)."""
        for i, ev in self.plan.at(step):
            if i in self._consumed or ev.kind != "slow_step":
                continue
            dur = float(ev.mode) if ev.mode else 0.05
            self._fire(i, ev, sleep_s=dur, **(context or {}))
            self.sleep(dur)

    def admit_hook(self, request):
        """``DCLServingEngine(admit_hook=...)``: corrupt submitted
        requests.  ``malformed_request`` replaces the image with a
        rank-1 plane; ``bucket_miss_storm`` diverts this and the next
        ``mode - 1`` (default 3 total) requests to a resolution no
        bucket matches.  Returns the (possibly mutated) request."""
        if self._storm_left > 0:
            self._storm_left -= 1
            request.image = self._off_bucket(request.image)
            return request
        if not self._armed_admission:
            return request
        i = self._armed_admission[0]
        ev = self.plan.events[i]
        if ev.kind == "bucket_miss_storm":
            self._armed_admission.pop(0)
            burst = int(ev.mode) if ev.mode else 3
            self._fire(i, ev, burst=burst)
            self._storm_left = burst - 1
            request.image = self._off_bucket(request.image)
        elif ev.kind == "malformed_request":
            self._armed_admission.pop(0)
            self._fire(i, ev)
            request.image = np.full((5,), np.nan, np.float32)
        return request

    @staticmethod
    def _off_bucket(image) -> np.ndarray:
        """A zero image at a resolution that misses every power-aligned
        bucket (odd extents, larger than the original)."""
        arr = np.asarray(image)
        h = (arr.shape[0] if arr.ndim >= 2 else 8) + 1
        w = (arr.shape[1] if arr.ndim >= 2 else 8) + 3
        return np.zeros((h | 1, w | 1, 3), np.float32)

    # -- telemetry -----------------------------------------------------
    def telemetry(self) -> dict:
        return {"plan": self.plan.summary(), "fired": list(self.fired)}

    def dump_telemetry(self, path, extra: dict | None = None) -> None:
        dump_telemetry(path, self.telemetry(), extra)
