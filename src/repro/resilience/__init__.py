"""Structured fault injection and recovery (PR 6).

``faults`` is the chaos harness: a seeded :class:`FaultPlan` drives
composable injectors through the Trainer's ``fault_hook``/``batch_hook``
seams and the kernel dispatcher's ``set_dispatch_hook`` seam, so a
chaos run is exactly reproducible from its seed.  The recovery
machinery itself lives where the state lives — the Trainer (sentinels,
retry, preemption), ``repro.checkpoint`` (CRC-verified restore with
fallback), and ``repro.kernels.ops`` (graceful degradation to the XLA
reference path) — this package only *breaks* things, on schedule.
"""
from .faults import (FAULT_KINDS, ChaosHooks, DataPipelineHiccup,
                     DeviceLost, FaultEvent, FaultInjected, FaultPlan,
                     KernelDispatchFault, corrupt_checkpoint,
                     dump_telemetry)

__all__ = [
    "FAULT_KINDS", "ChaosHooks", "DataPipelineHiccup", "DeviceLost",
    "FaultEvent", "FaultInjected", "FaultPlan", "KernelDispatchFault",
    "corrupt_checkpoint", "dump_telemetry",
]
